"""Production training driver.

Wires together configs → mesh → sharded train step → fault-tolerant
loop (auto-resume, async checkpoints, straggler telemetry, preemption
via SIGTERM). On this CPU container it runs the smoke configs end to end
(examples/train_lm.py); on a TPU pod slice the same driver runs the full
configs — only ``--mesh`` changes.

  PYTHONPATH=src python -m repro.launch.train --arch yi-34b --smoke \
      --steps 100 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import logging
import signal

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLMDataset
from repro.models import lm
from repro.optim import adamw, cosine_warmup, opt_state_specs
from repro.runtime import TrainLoop, TrainLoopConfig, make_train_step
from repro.runtime.steps import train_state_specs
from repro.sharding import Rules, tree_specs
from repro.launch.mesh import make_production_mesh, make_smoke_mesh


def build(args):
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.backend:
        cfg = cfg.with_backend(args.backend)

    if args.mesh == "none":
        mesh = None
        rules = Rules.null()
    else:
        mesh = (make_production_mesh(multi_pod=args.mesh == "multi")
                if args.mesh in ("single", "multi") else make_smoke_mesh())
        rules = Rules.for_mesh(mesh)

    optimizer = adamw(
        cosine_warmup(args.lr, warmup=args.warmup, total=args.steps),
        weight_decay=0.1)
    step = make_train_step(cfg, rules, optimizer, n_micro=args.accum,
                           grad_compress=args.grad_compress)

    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = optimizer.init(params)

    dataset = SyntheticLMDataset(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, seed=args.seed)

    if mesh is None:
        jitted = jax.jit(step)
        put = lambda b: {k: jnp.asarray(v) for k, v in b.items()}  # noqa
    else:
        pspecs, ospecs, bspecs = train_state_specs(cfg, rules)
        shp = jax.tree.map(lambda x: x.shape, params)
        p_sh = jax.tree.map(
            lambda ps: jax.sharding.NamedSharding(mesh, ps),
            tree_specs(pspecs, rules, shp),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        o_sh = jax.tree.map(
            lambda ps: jax.sharding.NamedSharding(mesh, ps),
            tree_specs(opt_state_specs(pspecs), rules,
                       jax.tree.map(lambda x: x.shape, opt_state)),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        b_sh = jax.tree.map(
            lambda ps: jax.sharding.NamedSharding(mesh, ps),
            tree_specs(bspecs, rules),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        put = lambda b: jax.device_put(  # noqa: E731
            {k: jnp.asarray(v) for k, v in b.items()}, b_sh)

    loop = TrainLoop(
        jitted, params, opt_state, dataset,
        TrainLoopConfig(total_steps=args.steps,
                        ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir,
                        fail_at_step=args.fail_at_step,
                        log_every=args.log_every),
        put_batch=put)
    # TPU maintenance events arrive as SIGTERM
    signal.signal(signal.SIGTERM,
                  lambda *_: loop.request_preemption())
    return loop


def main() -> int:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--backend", default=None,
                    choices=[None, "softmax", "linear", "gated_linear"])
    ap.add_argument("--mesh", default="none",
                    choices=["none", "auto", "single", "multi"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    args = ap.parse_args()

    loop = build(args)
    out = loop.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"final step {out['step']}  loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}  stragglers={len(out['straggler_events'])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
