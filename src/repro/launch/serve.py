"""Serving driver — the paper's deployment story.

Two modes:

* ``generate`` — autoregressive generation with batched requests:
  prefill once, then O(k²)-per-token decode under the linear backends
  (no KV cache; the 500k-context state is the same size as the 1-token
  state). ``--backend softmax`` serves the KV-cache baseline.
* ``retrieve`` — the §2.2 mass-query scenario: encode documents into the
  fixed-size DocumentStore once, then answer query streams at O(k²) each.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --smoke \
      --backend linear --prompt-len 64 --gen-len 32 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.sharding import Rules


def generate(args) -> int:
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.backend:
        cfg = cfg.with_backend(args.backend)
    rules = Rules.null()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)

    b, t_p, t_g = args.batch, args.prompt_len, args.gen_len
    prompt = jax.random.randint(key, (b, t_p), 0, cfg.vocab_size)
    memory = (jax.random.normal(key, (b, cfg.n_img_tokens, cfg.d_model),
                                jnp.bfloat16)
              if cfg.n_img_tokens else None)

    prefill = jax.jit(lambda p, toks: lm.prefill(p, toks, cfg, rules,
                                                 memory=memory))
    decode = jax.jit(lambda p, st, tok, pos: lm.decode_step(
        p, st, tok, pos, cfg, rules))

    t0 = time.perf_counter()
    logits, states = prefill(params, prompt)
    states = lm.pad_decode_state(states, cfg, max_len=t_p + t_g)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(t_g - 1):
        logits, states = decode(params, states, tok,
                                jnp.int32(t_p + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    state_bytes = sum(x.nbytes for x in jax.tree.leaves(states))
    print(f"arch={cfg.name} backend={cfg.attention_backend}")
    print(f"prefill {t_p} toks x{b}: {t_prefill*1e3:.0f} ms")
    print(f"decode  {t_g} toks x{b}: "
          f"{t_decode/max(t_g-1,1)*1e3:.1f} ms/tok")
    print(f"decode state: {state_bytes/2**20:.1f} MiB "
          f"({'O(1) in context' if cfg.fixed_state_decode else 'KV cache'})")
    return 0


def retrieve(args) -> int:
    """Encode-once / query-many with the DocumentStore."""
    from repro.core import DocumentState, DocumentStore
    key = jax.random.PRNGKey(args.seed)
    k_dim, n, docs = 100, 750, args.batch
    store = DocumentStore()
    h = jax.random.normal(key, (docs, n, k_dim))
    for i in range(docs):
        store.add(f"doc{i}", DocumentState.from_hidden_states(h[i]))
    q = jax.random.normal(jax.random.fold_in(key, 1),
                          (docs, k_dim))
    ids = [f"doc{i}" for i in range(docs)]
    store.batched_lookup(ids, q).block_until_ready()
    t0 = time.perf_counter()
    iters = 100
    for _ in range(iters):
        out = store.batched_lookup(ids, q)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    print(f"store: {len(store)} docs, {store.nbytes/2**20:.1f} MiB "
          f"(raw hidden states would be {h.nbytes/2**20:.1f} MiB)")
    print(f"lookup: {docs/dt:.0f} queries/s, O(k²) each")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="generate",
                    choices=["generate", "retrieve"])
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=[None, "softmax", "linear", "gated_linear"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    return generate(args) if args.mode == "generate" else retrieve(args)


if __name__ == "__main__":
    raise SystemExit(main())
