"""Serving driver — the paper's deployment story.

Three modes:

* ``generate`` — autoregressive generation with one static batch of
  requests: prefill once, then O(k²)-per-token decode under the linear
  backends (no KV cache; the 500k-context state is the same size as the
  1-token state). ``--backend softmax`` serves the KV-cache baseline.

  The generation loop is FUSED: the whole decode phase is one
  ``lm.generate`` dispatch (a ``lax.scan`` over decode steps with
  greedy/temperature sampling folded in), and inside each step the
  linear-family state update runs through the fused recurrent Pallas
  kernels (``kernels/fused_recurrent``) — state resident in VMEM,
  updated in place in HBM via input/output aliasing. Per-token cost is
  therefore FLOPs-dominated instead of dispatch/HBM-traffic-dominated:
  the pre-fusion driver paid one jitted dispatch + a full decode-state
  HBM round-trip per token.

* ``stream`` — continuous batching under a synthetic Poisson request
  stream (the paper's §2.2 "extreme query loads" as a scheduling
  problem): requests with exponential inter-arrival times and a skewed
  generation-length mix are driven through the fixed-slot
  :class:`repro.serving.DecodeEngine`. Freed slots are refilled between
  scan segments by bucket-padded BATCHED varlen prefill (one dispatch
  per admission wave, O(log prefill_chunk) compiled programs total);
  prompts longer than ``--prefill-chunk`` are ingested in masked
  varlen-window chunks interleaved with decode segments, so neither a
  long straggler nor a long prompt idles the rest of the batch
  (``--admission per_request`` selects the PR-2 host-blocking
  prefill-on-admit baseline). Reports aggregate tokens/s, slot
  utilization and admission stats (batch sizes, jit misses,
  chunk-interleave ratio). ``--backends linear,softmax,mamba2``
  serves a HETEROGENEOUS FLEET instead: one slot group per backend
  family behind a single admission queue
  (:class:`repro.serving.FleetEngine`), requests round-robined across
  groups, one compiled segment program per backend.

* ``spec`` — speculative lookahead decoding through the slot engine: a
  draft provider proposes K tokens per round and ONE ``lm.decode_window``
  launch verifies the whole window per slot (the paper's fixed-size
  state makes verify/rewind an O(k²) copy instead of a KV-cache replay).
  Greedy outputs are exactly the plain-greedy tokens — the mode runs the
  same workload plain first and asserts token equality, then reports the
  acceptance rate and the speculative/plain tokens/s ratio.
  ``--draft ngram`` (default) drafts by prompt-lookup suffix matching at
  zero device cost; ``--draft model`` drafts with a second (here:
  same-config) LM through its own fixed-size slot states.

* ``retrieve`` — the §2.2 mass-query scenario: encode documents into the
  fixed-size DocumentStore once, then answer query streams at O(k²) each.

* ``lookup`` — the memory-serving engine
  (:class:`repro.serving.LookupEngine`): documents are GRU-encoded ONCE
  in varlen batched ingest waves, pinned resident as one stacked
  (N, k, k) store, and a query storm against arbitrary different
  memories is served in bucket-padded waves — each wave ONE
  ``mass_lookup_indexed`` kernel dispatch. ``--lookup-backend softmax``
  serves the honest baseline (full hidden states resident, per-query
  cost grows with --doc-len); ``--load PATH`` pins a persisted
  DocumentStore instead of synthesising documents. Reuses the
  bounded-queue knobs (``--max-queue``/``--shed-policy``).

  PYTHONPATH=src python -m repro.launch.serve --mode lookup \
      --n-docs 256 --doc-len 64 --n-queries 2048 --lookup-backend linear

  PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --smoke \
      --backend linear --prompt-len 64 --gen-len 32 --batch 4
  PYTHONPATH=src python -m repro.launch.serve --mode stream --smoke \
      --backend linear --slots 4 --n-requests 16 --arrival-rate 0.5
  PYTHONPATH=src python -m repro.launch.serve --mode stream \
      --backends linear,softmax,mamba2 --slots 2 --n-requests 9
  PYTHONPATH=src python -m repro.launch.serve --mode spec --smoke \
      --backend linear --slots 4 --n-requests 8 --speculate-k 6
"""

from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.sharding import Rules


def generate(args) -> int:
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.backend:
        cfg = cfg.with_backend(args.backend)
    rules = Rules.null()
    # independent PRNG streams — params/prompt/memory/sampling must not
    # share a key (identical draws correlate weights with data)
    root = jax.random.PRNGKey(args.seed)
    k_params, k_prompt, k_memory, k_sample = (
        jax.random.fold_in(root, i) for i in range(4))
    params = lm.init_params(k_params, cfg)

    b, t_p, t_g = args.batch, args.prompt_len, args.gen_len
    prompt = jax.random.randint(k_prompt, (b, t_p), 0, cfg.vocab_size)
    memory = (jax.random.normal(k_memory,
                                (b, cfg.n_img_tokens, cfg.d_model),
                                jnp.bfloat16)
              if cfg.n_img_tokens else None)

    prefill = jax.jit(lambda p, toks: lm.prefill(p, toks, cfg, rules,
                                                 memory=memory))
    # ONE dispatch for the whole generation: scan + fused kernels inside
    gen = jax.jit(lambda p, st, tok, key: lm.generate(
        p, st, tok, t_p, t_g - 1, cfg, rules,
        temperature=args.temperature, key=key))

    t0 = time.perf_counter()
    logits, states = prefill(params, prompt)
    states = lm.pad_decode_state(states, cfg, max_len=t_p + t_g)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    k_first, k_rest = jax.random.split(k_sample)
    tok0 = lm.sample_token(logits, args.temperature, k_first)
    jax.block_until_ready(gen(params, states, tok0, k_rest)[0])  # compile
    t0 = time.perf_counter()
    toks, states = gen(params, states, tok0, k_rest)
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0
    out = jnp.concatenate([tok0[:, None], toks], axis=1)
    assert out.shape == (b, t_g)

    state_bytes = sum(x.nbytes for x in jax.tree.leaves(states))
    n_dec = max(t_g - 1, 1)
    print(f"arch={cfg.name} backend={cfg.attention_backend} "
          f"decode_kernel={cfg.decode_kernel}")
    print(f"prefill {t_p} toks x{b}: {t_prefill*1e3:.0f} ms")
    print(f"decode  {t_g} toks x{b}: {t_decode/n_dec*1e3:.2f} ms/tok "
          f"({b*n_dec/t_decode:.0f} tok/s, single dispatch)")
    print(f"decode state: {state_bytes/2**20:.1f} MiB "
          f"({'O(1) in context' if cfg.fixed_state_decode else 'KV cache'})")
    return 0


def make_request_mix(rng: np.random.Generator, n_requests: int,
                     prompt_len: int, gen_len: int, vocab_size: int,
                     arrival_rate: float):
    """Synthetic workload: Poisson arrivals (exponential inter-arrival
    times, ``arrival_rate`` requests per decode step; 0 = all at once)
    and a skewed generation-length mix — most requests are short,
    every 4th runs ``gen_len`` tokens (the straggler pattern continuous
    batching exists for)."""
    t = 0.0
    out = []
    for i in range(n_requests):
        if arrival_rate > 0:
            t += rng.exponential(1.0 / arrival_rate)
        prompt = rng.integers(0, vocab_size, size=prompt_len,
                              dtype=np.int64).astype(np.int32)
        g = gen_len if i % 4 == 0 else max(1, gen_len // 8)
        out.append((prompt, g, t))
    return out


class _Drainer:
    """SIGINT/SIGTERM → finish the in-flight segment, drain, exit 0.

    The handler only sets a flag; the serving loop checks it between
    scheduler events, so a signal never tears a segment (or a
    checkpoint write) in half. A second signal falls back to the
    default handler — the escape hatch if draining itself wedges."""

    def __init__(self):
        self.requested = False
        self._prev = {}

    def __enter__(self):
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                self._prev[sig] = signal.signal(sig, self._on_signal)
            except ValueError:          # non-main thread (tests)
                pass
        return self

    def __exit__(self, *exc):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)

    def _on_signal(self, sig, frame):
        self.requested = True
        signal.signal(sig, self._prev.get(sig, signal.SIG_DFL))


def stream_fleet(args) -> int:
    """Heterogeneous fleet streaming: the Poisson workload round-robins
    across N backend slot groups behind ONE admission queue
    (``--backends linear,softmax,mamba2``; smoke-scale fleet demo
    configs — they share the vocab, so one request mix feeds every
    architecture family at once). ``--replicas N`` runs every group as
    N replicas behind the same queue (heartbeat + breaker failover)."""
    from repro.serving import FleetEngine, fleet_demo_config

    names = [b.strip() for b in args.backends.split(",") if b.strip()]
    root = jax.random.PRNGKey(args.seed)
    groups = {}
    for i, name in enumerate(names):
        cfg = fleet_demo_config(name)
        groups[name] = (lm.init_params(jax.random.fold_in(root, i), cfg),
                        cfg)
    max_len = args.prompt_len + args.gen_len + args.segment_len
    fleet = FleetEngine(
        groups, n_slots=args.slots, segment_len=args.segment_len,
        max_len=max_len, temperature=args.temperature, seed=args.seed,
        max_queue=getattr(args, "max_queue", None),
        shed_policy=getattr(args, "shed_policy", "reject_new"),
        replicas=getattr(args, "replicas", 1),
        journal_dir=getattr(args, "journal_dir", None),
        checkpoint_dir=getattr(args, "checkpoint_dir", None),
        prefix_cache={"off": None, "auto": "auto", "on": True}[
            getattr(args, "prefix_cache", "off")],
        cache_bytes=getattr(args, "cache_bytes", 64 << 20))
    vocab = min(cfg.vocab_size for _, cfg in groups.values())
    rng = np.random.default_rng(args.seed)
    requests = make_request_mix(rng, args.n_requests, args.prompt_len,
                                args.gen_len, vocab, args.arrival_rate)
    routed = {}
    for i, (prompt, g, arrival) in enumerate(requests):
        uid = fleet.submit(prompt, g, backend=names[i % len(names)],
                           arrival=arrival)
        routed[uid] = names[i % len(names)]

    t0 = time.perf_counter()
    completions = fleet.run("continuous")
    dt = time.perf_counter() - t0

    total = sum(len(c.tokens) for c in completions)
    print(f"fleet backends={','.join(names)} slots={args.slots}/group "
          f"segment={args.segment_len}")
    print(f"stream: {len(completions)} requests, {total} tokens in "
          f"{dt:.2f} s ({total/dt:.0f} tok/s incl. compile)")
    stats = fleet.stats()
    for name in names:
        g = stats["groups"][name]
        toks = sum(len(c.tokens) for c in completions
                   if routed.get(c.uid) == name)
        print(f"  {name}: {toks} toks, backend={g['backend']} "
              f"fixed_state={g['fixed_size_state']} "
              f"state/slot={g['state_bytes_per_slot']/1024:.1f} KiB, "
              f"{g['compiled_segment_programs']} segment program(s), "
              f"slot util {g['stats']['slot_utilization']:.2f}")
    programs = fleet.compiled_segment_programs()
    print(f"compiled segment programs: {programs} "
          f"(one per backend: {all(v == 1 for v in programs.values())})")
    if getattr(args, "replicas", 1) > 1:
        print(f"replicas={args.replicas}/group "
              f"failovers={stats['failovers']} "
              f"readmitted={stats['readmitted']}")
    assert len(completions) == args.n_requests
    return 0


def stream(args) -> int:
    """Continuous batching under a synthetic Poisson request stream."""
    from repro.serving import DecodeEngine

    if getattr(args, "replicas", 1) > 1 and not getattr(
            args, "backends", None):
        args.backends = args.backend or "linear"
    if getattr(args, "backends", None):
        return stream_fleet(args)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.backend:
        cfg = cfg.with_backend(args.backend)
    rules = Rules.null()
    root = jax.random.PRNGKey(args.seed)
    params = lm.init_params(jax.random.fold_in(root, 0), cfg)

    from repro.serving import FaultInjector, InjectedCrash

    crash_at = getattr(args, "crash_at_event", None)
    injector = (FaultInjector(crash=(crash_at,))
                if crash_at is not None else None)
    max_len = args.prompt_len + args.gen_len + args.segment_len
    engine = DecodeEngine(
        params, cfg, rules, n_slots=args.slots,
        segment_len=args.segment_len, max_len=max_len,
        temperature=args.temperature, seed=args.seed,
        admission=getattr(args, "admission", "auto"),
        prefill_chunk=getattr(args, "prefill_chunk", 64),
        max_queue=getattr(args, "max_queue", None),
        shed_policy=getattr(args, "shed_policy", "reject_new"),
        degrade_threshold=getattr(args, "degrade_threshold", None),
        journal=getattr(args, "journal", None),
        checkpoint_dir=getattr(args, "checkpoint_dir", None),
        checkpoint_every=getattr(args, "checkpoint_every", 0),
        prefix_cache={"off": None, "auto": "auto", "on": True}[
            getattr(args, "prefix_cache", "off")],
        cache_bytes=getattr(args, "cache_bytes", 64 << 20),
        injector=injector)

    if getattr(args, "recover", False):
        if engine.journal is None and engine._ckpt_mgr is None:
            raise SystemExit(
                "--recover needs --journal and/or --checkpoint-dir")
        n_journaled = len(engine.journal.unacked_submits()) \
            if engine.journal is not None else 0
        engine.recover_in_place()
        print(f"recover: {n_journaled} unacked request(s) replayed "
              f"from the journal")
    else:
        rng = np.random.default_rng(args.seed)
        requests = make_request_mix(rng, args.n_requests,
                                    args.prompt_len, args.gen_len,
                                    cfg.vocab_size, args.arrival_rate)
        fork = getattr(args, "fork", 1)
        for prompt, g, arrival in requests:
            engine.submit(prompt, g, arrival=arrival, fork=fork)

    t0 = time.perf_counter()
    with _Drainer() as drain:
        try:
            while engine.has_work() and not drain.requested:
                engine.step("continuous")
        except InjectedCrash as e:
            # simulated hard kill: NO drain, NO final checkpoint — the
            # journal + last periodic checkpoint are all recovery gets
            print(f"crash: injected at event {e.event_idx} "
                  f"(journal/checkpoint left as-is; restart with "
                  f"--recover)")
            return 3
    completions = engine.completions()
    dt = time.perf_counter() - t0

    if drain.requested:
        in_flight = sum(1 for s in engine._slot_req if s is not None) \
            + len(engine._queue) + len(engine._suspended)
        if engine._ckpt_mgr is not None:
            engine.save_checkpoint()
        print(f"graceful shutdown: segment finished, {in_flight} "
              f"in-flight request(s) "
              + ("journaled + checkpointed for --recover"
                 if engine.journal is not None
                 or engine._ckpt_mgr is not None else "dropped"))
        if getattr(args, "stats_json", None):
            with open(args.stats_json, "w") as f:
                f.write(engine.stats.to_json())
            print(f"stats written to {args.stats_json}")
        return 0

    if engine.journal is not None:
        acks = engine.journal.acked()
        uids = {c.uid for c in completions}
        lost = sorted(uids - set(acks))
        zero_loss = not lost and len(acks) == len(uids)
        print(f"durability: acks={len(acks)} completions={len(uids)} "
              f"lost={len(lost)} "
              f"zero_loss={'PASS' if zero_loss else 'FAIL'}")

    total = sum(len(c.tokens) for c in completions)
    served = [c for c in completions if c.admitted_step >= 0]
    lat = [c.finished_step - c.admitted_step for c in served]
    statuses = {}
    for c in completions:
        statuses[c.status] = statuses.get(c.status, 0) + 1
    print(f"arch={cfg.name} backend={cfg.attention_backend} "
          f"slots={args.slots} segment={args.segment_len}")
    print(f"stream: {len(completions)} requests, {total} tokens in "
          f"{dt:.2f} s ({total/dt:.0f} tok/s incl. compile)")
    st = engine.stats
    print(f"slot utilization {st.slot_utilization:.2f} over "
          f"{st.segments} segments; mean latency "
          f"{np.mean(lat):.0f} decode steps" if served else
          "no request was served")
    print("status: " + " ".join(
        f"{k}={v}" for k, v in sorted(statuses.items())))
    if st.shed or st.preemptions or st.quarantined or st.degrade_transitions:
        print(f"lifecycle: shed={st.shed} preempt={st.preemptions} "
              f"resume={st.resumes} quarantine={st.quarantined} "
              f"retries={st.retries} failed={st.failed} "
              f"degrade_flips={st.degrade_transitions}")
    print(f"admission={engine.admission} chunk={engine.prefill_chunk}: "
          f"{st.prefills} prompts in {st.admission_batches} batched "
          f"waves (mean batch {st.mean_admission_batch:.1f}), "
          f"{st.ingest_chunks} ingest chunks "
          f"(interleave {st.interleave_ratio:.2f}), "
          f"{st.prefill_jit_misses} admission jit misses")
    if engine.cache is not None:
        c = engine.cache.counters()
        print(f"prefix cache ({engine.cache.name}): "
              f"hits={st.cache_hits} misses={st.cache_misses} "
              f"cached_prefix_tokens={st.cached_prefix_tokens} "
              f"forks={st.forks} evictions={st.cache_evictions} "
              f"bytes={c['bytes_used']}/{engine.cache.max_bytes}")
    if getattr(args, "stats_json", None):
        with open(args.stats_json, "w") as f:
            f.write(engine.stats.to_json())
        print(f"stats written to {args.stats_json}")
    # every submitted request resolves to a completion — shed/deadline
    # ones included (that's the bounded-queue contract); a recovered
    # run's request count comes from the journal, not --n-requests.
    # fork=N submissions resolve to N completions each.
    if not getattr(args, "recover", False):
        assert len(completions) == args.n_requests * getattr(
            args, "fork", 1)
    return 0


def spec(args) -> int:
    """Speculative lookahead vs plain continuous batching, same workload."""
    import dataclasses

    from repro.serving import DecodeEngine, ModelDraft, NgramDraft

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.backend:
        cfg = cfg.with_backend(args.backend)
    # fp32 activations: the mode ASSERTS spec == plain greedy, and the
    # windowed verify accumulates in a different association order than
    # the sequential step — fp32 keeps argmax margins above that noise
    # (bf16 could flip a near-tie and fail the assert spuriously)
    cfg = dataclasses.replace(cfg, dtype="float32")
    rules = Rules.null()
    root = jax.random.PRNGKey(args.seed)
    params = lm.init_params(jax.random.fold_in(root, 0), cfg)

    k = args.speculate_k
    max_len = args.prompt_len + args.gen_len + max(args.segment_len, k) + 1
    if args.draft == "ngram":
        draft = NgramDraft()
    else:
        dparams = lm.init_params(jax.random.fold_in(root, 1), cfg)
        draft = ModelDraft(dparams, cfg, rules, n_slots=args.slots,
                           max_len=max_len)
    engine = DecodeEngine(
        params, cfg, rules, n_slots=args.slots,
        segment_len=args.segment_len, max_len=max_len, seed=args.seed,
        draft=draft, admission=getattr(args, "admission", "auto"),
        prefill_chunk=getattr(args, "prefill_chunk", 64))
    rng = np.random.default_rng(args.seed)
    requests = [(rng.integers(0, cfg.vocab_size, size=args.prompt_len,
                              dtype=np.int64).astype(np.int32),
                 args.gen_len) for _ in range(args.n_requests)]

    def run_once(speculate_k):
        engine.reset()
        for prompt, g in requests:
            engine.submit(prompt, g, speculate_k=speculate_k)
        t0 = time.perf_counter()
        comps = engine.run("continuous")
        return comps, time.perf_counter() - t0

    run_once(k)                                   # compile both paths
    run_once(0)
    comps_plain, t_plain = run_once(0)
    comps_spec, t_spec = run_once(k)
    for a, b in zip(comps_plain, comps_spec):
        assert np.array_equal(a.tokens, b.tokens), \
            f"speculative decode diverged from plain greedy on {a.uid}"

    total = sum(len(c.tokens) for c in comps_spec)
    st = engine.stats
    print(f"arch={cfg.name} backend={cfg.attention_backend} "
          f"slots={args.slots} speculate_k={k} draft={args.draft}")
    print(f"spec:  {total} tokens in {t_spec:.2f} s "
          f"({total/t_spec:.0f} tok/s) — acceptance "
          f"{st.acceptance_rate:.2f}, {st.spec_rounds} rounds, "
          f"{st.spec_rewinds} rewinds in "
          f"{st.spec_rewind_dispatches} varlen dispatches")
    print(f"plain: {total} tokens in {t_plain:.2f} s "
          f"({total/t_plain:.0f} tok/s) — speculative speedup "
          f"{t_plain/t_spec:.2f}x, outputs bit-identical")
    if getattr(args, "stats_json", None):
        with open(args.stats_json, "w") as f:
            f.write(engine.stats.to_json())
        print(f"stats written to {args.stats_json}")
    return 0


def retrieve(args) -> int:
    """Encode-once / query-many with the DocumentStore."""
    from repro.core import DocumentState, DocumentStore
    key = jax.random.PRNGKey(args.seed)
    k_dim, n, docs = 100, 750, args.batch
    store = DocumentStore()
    h = jax.random.normal(key, (docs, n, k_dim))
    for i in range(docs):
        store.add(f"doc{i}", DocumentState.from_hidden_states(h[i]))
    q = jax.random.normal(jax.random.fold_in(key, 1),
                          (docs, k_dim))
    ids = [f"doc{i}" for i in range(docs)]
    store.batched_lookup(ids, q).block_until_ready()
    t0 = time.perf_counter()
    iters = 100
    for _ in range(iters):
        out = store.batched_lookup(ids, q)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    print(f"store: {len(store)} docs, {store.nbytes/2**20:.1f} MiB "
          f"(raw hidden states would be {h.nbytes/2**20:.1f} MiB)")
    print(f"lookup: {docs/dt:.0f} queries/s, O(k²) each")
    return 0


def lookup(args) -> int:
    """Memory-serving: ingest once, pin resident, serve query waves."""
    from repro.qa.gru import gru_params
    from repro.serving import LookupEngine

    k_dim, vocab, d_embed = 64, 1000, 32
    root = jax.random.PRNGKey(args.seed)
    k_embed, k_gru, k_query = (jax.random.fold_in(root, i)
                               for i in range(3))
    encoder = {"embed": jax.random.normal(k_embed, (vocab, d_embed)) * 0.1,
               "gru": gru_params(k_gru, d_embed, k_dim)}
    engine = LookupEngine(
        encoder, backend=args.lookup_backend, wave_size=args.wave_size,
        max_queue=getattr(args, "max_queue", None),
        shed_policy=getattr(args, "shed_policy", "reject_new"))

    rng = np.random.default_rng(args.seed)
    if args.load:
        from repro.core import DocumentStore
        store = DocumentStore.load(args.load)
        for doc_id in store.ids():
            engine.pin(doc_id, store.get(doc_id))
        print(f"pinned {len(engine)} persisted memories from {args.load}")
    else:
        for i in range(args.n_docs):
            engine.ingest(f"doc{i}", rng.integers(0, vocab,
                                                  size=args.doc_len))
        engine.flush()
    doc_ids = list(engine.rows())

    queries = np.asarray(jax.random.normal(
        k_query, (args.n_queries, k_dim), jnp.float32))
    for i in range(args.n_queries):           # warm the wave programs
        engine.submit(doc_ids[i % len(doc_ids)], queries[i])
    engine.run()
    warm = engine.stats.queries
    for i in range(args.n_queries):
        engine.submit(doc_ids[(i * 7) % len(doc_ids)], queries[i],
                      priority=i % 3)
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0

    st = engine.stats
    served = st.queries - warm
    print(f"lookup backend={st.backend} "
          f"fixed_size_memory={engine.backend.fixed_size_memory}")
    print(f"memories: {st.documents} resident "
          f"({st.ingest_waves} varlen ingest waves = "
          f"{st.ingest_dispatches} dispatches, {st.pinned} pinned), "
          f"{engine.resident_bytes/2**20:.2f} MiB")
    print(f"serve: {served} queries in {dt:.3f} s "
          f"({served/max(dt, 1e-9):.0f} lookups/s) — "
          f"{st.waves} waves = {st.lookup_dispatches} dispatches "
          f"({st.queries_per_wave:.1f} queries/wave, "
          f"{st.multi_memory_waves} mixed-memory waves)")
    if st.shed:
        print(f"shed: {st.shed} (policy={engine.shed_policy})")
    if getattr(args, "stats_json", None):
        with open(args.stats_json, "w") as f:
            f.write(st.to_json())
        print(f"stats written to {args.stats_json}")
    assert st.lookup_dispatches == st.waves, "one dispatch per wave"
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="generate",
                    choices=["generate", "stream", "spec", "retrieve",
                             "lookup"])
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=[None, "softmax", "linear", "gated_linear"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 = categorical sampling")
    ap.add_argument("--seed", type=int, default=0)
    # stream mode (continuous batching)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--segment-len", type=int, default=8)
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="requests per decode step (0 = all at t=0)")
    ap.add_argument("--backends", default=None, metavar="A,B,...",
                    help="serve a heterogeneous fleet (stream mode): "
                         "comma-separated backend groups, e.g. "
                         "linear,softmax,mamba2 — one slot group per "
                         "backend behind a single admission queue "
                         "(smoke-scale fleet demo configs)")
    ap.add_argument("--admission", default="auto",
                    choices=["auto", "batched", "per_request"],
                    help="prompt ingestion: bucket-padded batched varlen"
                         " prefill + chunked ingest (batched) vs the"
                         " host-blocking prefill-on-admit baseline")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="max prompt tokens per ingest dispatch (rounded"
                         " up to a power of two); longer prompts are"
                         " chunked and interleaved with decode segments")
    # prefix caching (stream mode)
    ap.add_argument("--prefix-cache", default="off",
                    choices=["off", "auto", "on"],
                    help="content-hash prefix cache: shared prompt"
                         " prefixes admit as ONE state copy + suffix-"
                         "only prefill (fixed-size states) or reuse"
                         " refcounted KV blocks (softmax); 'on' errors"
                         " if the backend can't cache, 'auto' degrades"
                         " to off")
    ap.add_argument("--cache-bytes", type=int, default=64 << 20,
                    help="prefix-cache byte budget (LRU eviction)")
    ap.add_argument("--fork", type=int, default=1, metavar="N",
                    help="n-best: admit each prompt once and fork N"
                         " continuation slots off the shared prefill"
                         " (uids uid..uid+N-1)")
    # robustness knobs (stream mode)
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the admission queue; a full queue sheds"
                         " per --shed-policy (status='shed')")
    ap.add_argument("--shed-policy", default="reject_new",
                    choices=["reject_new", "evict_lowest"])
    ap.add_argument("--degrade-threshold", type=float, default=None,
                    help="waiting requests per slot beyond which the"
                         " engine degrades (spec off, smaller ingest"
                         " chunks); None disables")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="write EngineStats (counters + lifecycle/chaos"
                         " fields) to PATH as JSON")
    # durability (stream mode)
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="write-ahead request journal: every submit/"
                         "cancel/ack is fsync'd to PATH before it takes"
                         " effect; a restarted engine replays it")
    ap.add_argument("--journal-dir", default=None, metavar="DIR",
                    help="fleet mode: per-replica journals under DIR")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="engine checkpoints (slot states + scheduler)"
                         " under DIR; atomic, keep-N retention")
    ap.add_argument("--checkpoint-every", type=int, default=4,
                    metavar="N", help="checkpoint every N scheduler"
                    " events (0 = only on graceful shutdown)")
    ap.add_argument("--recover", action="store_true",
                    help="restore the newest checkpoint, replay the"
                         " journal past it, and finish the stranded"
                         " work instead of submitting new requests")
    ap.add_argument("--replicas", type=int, default=1,
                    help="fleet mode: replicas per backend group"
                         " (heartbeat + circuit-breaker failover)")
    ap.add_argument("--crash-at-event", type=int, default=None,
                    metavar="N", help="chaos: hard-kill the engine at"
                    " scheduler event N (exit 3; restart with"
                    " --recover)")
    # lookup mode (memory serving)
    ap.add_argument("--n-docs", type=int, default=128,
                    help="lookup mode: memories to ingest")
    ap.add_argument("--doc-len", type=int, default=64,
                    help="lookup mode: tokens per synthetic document")
    ap.add_argument("--n-queries", type=int, default=1024,
                    help="lookup mode: queries in the storm")
    ap.add_argument("--wave-size", type=int, default=64,
                    help="lookup mode: max requests per query wave")
    ap.add_argument("--lookup-backend", default="linear",
                    choices=["linear", "softmax"],
                    help="fixed-size k×k memories through the indexed "
                         "Pallas kernel vs the full-hidden-state "
                         "softmax baseline")
    ap.add_argument("--load", default=None, metavar="PATH",
                    help="lookup mode: pin a persisted DocumentStore "
                         "(.npz) instead of synthesising documents")
    # spec mode (speculative lookahead)
    ap.add_argument("--speculate-k", type=int, default=6,
                    help="draft tokens per verify round")
    ap.add_argument("--draft", default="ngram",
                    choices=["ngram", "model"],
                    help="draft provider: prompt-lookup n-grams (free) "
                         "or a second LM with its own slot states")
    args = ap.parse_args()
    if args.mode == "lookup" and args.load and \
            args.lookup_backend != "linear":
        ap.error(
            f"--load pins a persisted compressed (k×k) DocumentStore, "
            f"which only the fixed-size linear backend can serve; "
            f"--lookup-backend {args.lookup_backend} keeps full "
            f"hidden states resident and cannot pin compressed "
            f"memories (drop --load and ingest documents instead)")
    if args.mode == "stream":
        return stream(args)
    if args.mode == "spec":
        return spec(args)
    if args.mode == "lookup":
        return lookup(args)
    return generate(args) if args.mode == "generate" else retrieve(args)


if __name__ == "__main__":
    raise SystemExit(main())
