"""Post-SPMD HLO analysis: trip-count-aware FLOP / byte / collective
accounting.

Why not just ``compiled.cost_analysis()``:
  1. XLA's cost analysis counts a ``while`` body ONCE — the scan over
     layers (and any grad-accumulation loop) would be under-counted by
     the trip count (verified in tests/test_hlo_parse.py).
  2. The CPU backend's float-normalization pass rewrites bf16 compute to
     f32 AFTER partitioning, inflating byte counts 2× relative to the
     TPU target. The dump taken right after the ``spmd-partitioning``
     pass still has true dtypes.

So the dry-run compiles with ``--xla_dump_hlo_pass_re=spmd.*`` and this
module parses

  * the **post-SPMD dump** for dot-FLOPs and collective bytes (true
    dtypes, pre-fusion, while-structure intact), and
  * the **final executable text** for fusion-boundary HBM traffic (the
    only fusion-aware source; f32-inflation caveat documented in
    EXPERIMENTS.md §Roofline).

Both walks multiply by while-loop trip counts extracted from each loop
condition (``compare(induction, constant(N)), direction=LT``).

Per-collective wire bytes use ring-algorithm payloads with group size S
from ``replica_groups=[G,S]<=[N]``:

    all-reduce         2 · bytes · (S−1)/S     (reduce-scatter + all-gather)
    all-gather         bytes · (S−1)/S         (bytes = gathered result)
    reduce-scatter     bytes_result · (S−1)
    all-to-all         bytes · (S−1)/S
    collective-permute bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[^\s(]+)\s+([\w\-]+)")
# computation header: `%name (args...) -> rettype {` — args may contain
# nested parens (tuple types), so match greedily to the trailing `{`.
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")

# ops that move no HBM bytes themselves
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "reshape", "while", "conditional", "call",
    "partition-id", "replica-id", "rng-get-and-update-state", "domain",
    "opt-barrier", "custom-call",
}


def _shape_bytes(type_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_text: str) -> List[int]:
    m = _SHAPE_RE.search(type_text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _is_score_block(type_text: str) -> bool:
    """Float tensor with equal trailing dims ≥ 256 — an attention score
    block (f32 scores/probabilities or bf16 ds blocks), VMEM-resident
    under the Pallas kernels."""
    if not (type_text.startswith("f32[") or type_text.startswith("bf16[")):
        return False
    dims = _shape_dims(type_text)
    return len(dims) >= 2 and dims[-1] == dims[-2] and dims[-1] >= 256


def _is_attn_accum(type_text: str) -> bool:
    """f32 (…, block≥256, d) tensors in read-modify-write slices — the
    pair-scan's (acc, dq, dk, dv) accumulators. A Pallas flash kernel
    keeps them in VMEM scratch; decode KV caches are bf16 and state
    matrices have dims[-2] ≤ 128, so neither matches."""
    if not type_text.startswith("f32["):
        return False
    dims = _shape_dims(type_text)
    return len(dims) >= 3 and dims[-2] >= 256


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    computation: str
    multiplicity: int = 1

    @property
    def wire_bytes(self) -> float:
        s = max(self.group_size, 1)
        frac = (s - 1) / s if s > 1 else 0.0
        if self.kind == "all-reduce":
            return 2.0 * self.result_bytes * frac
        if self.kind == "all-gather":
            return self.result_bytes * frac
        if self.kind == "reduce-scatter":
            return float(self.result_bytes) * (s - 1)
        if self.kind == "all-to-all":
            return self.result_bytes * frac
        return float(self.result_bytes)


@dataclasses.dataclass
class ModuleAnalysis:
    """Trip-count-aware per-device totals for one HLO module."""
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    # HBM bytes of f32 square "score blocks" (trailing dims equal, ≥256):
    # the blocked-attention intermediates that a Pallas flash kernel keeps
    # in VMEM. memory term is reported with and without them.
    score_bytes: float = 0.0
    collectives: List[CollectiveOp] = dataclasses.field(default_factory=list)

    @property
    def collective_wire_bytes(self) -> float:
        return sum(o.wire_bytes * o.multiplicity for o in self.collectives)

    @property
    def collective_payload_bytes(self) -> float:
        return sum(o.result_bytes * o.multiplicity for o in self.collectives)

    def collective_by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for o in self.collectives:
            out[o.kind] = out.get(o.kind, 0.0) + \
                o.wire_bytes * o.multiplicity
        return out

    def collective_count(self) -> int:
        return sum(o.multiplicity for o in self.collectives)


# ---------------------------------------------------------------------------
# module structure
# ---------------------------------------------------------------------------

def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        if current is None:
            m = _HEADER_RE.match(line)
            if m:
                current = m.group(1)
                comps[current] = []
        else:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return comps


def _find_trip_count(cond_lines: List[str]) -> int:
    constants: Dict[str, int] = {}
    for line in cond_lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\S+\s+constant\((\d+)\)",
                     line)
        if m:
            constants[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        if " compare(" in line and "direction=LT" in line:
            m = re.search(r"compare\(([^)]*)\)", line)
            if m:
                for operand in m.group(1).split(","):
                    name = operand.strip().lstrip("%")
                    if name in constants:
                        return constants[name]
    return max(constants.values(), default=1)


def _multiplicities(text: str, comps: Dict[str, List[str]]) -> Dict[str, int]:
    edges: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}
    for name, lines in comps.items():
        for line in lines:
            wm = re.search(r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,"
                           r"\s*body=%?([\w.\-]+)", line)
            if wm:
                trips = _find_trip_count(comps.get(wm.group(1), []))
                edges[name].append((wm.group(2), trips))
                edges[name].append((wm.group(1), trips))  # cond also runs
                continue
            for cm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", line):
                if cm.group(1) in comps:
                    edges[name].append((cm.group(1), 1))
            bm = re.search(r"(?:true_computation|false_computation)="
                           r"%?([\w.\-]+)", line)
            if bm and bm.group(1) in comps:
                edges[name].append((bm.group(1), 1))

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
                break
    mult: Dict[str, int] = {}

    def visit(comp: str, m: int, depth: int = 0):
        if depth > 60 or comp not in comps:
            return
        mult[comp] = mult.get(comp, 0) + m
        for child, w in edges.get(comp, []):
            visit(child, m * w, depth + 1)

    if entry:
        visit(entry, 1)
    else:
        mult = {c: 1 for c in comps}
    return mult


# ---------------------------------------------------------------------------
# the analyzer
# ---------------------------------------------------------------------------

# "major" byte model (for the pre-fusion post-SPMD graph): ops that
# always touch HBM on TPU. Elementwise/convert chains, broadcasts, pads,
# slices, transposes and concats are assumed fused into their consumers
# (XLA:TPU fusion + Mosaic layout handling); dots read operands + write
# results; reductions read their data operand. Validated against an
# analytic per-layer traffic model for yi-34b in EXPERIMENTS.md §Roofline.
_MAJOR_READ_WRITE = {"dot", "convolution", "gather", "scatter", "copy"}
_MAJOR_RESULT_ONLY = {"reduce", "reduce-window", "sort"}


def analyze_module(
    text: str,
    *,
    count_flops: bool = True,
    count_bytes: bool = True,
    count_collectives: bool = True,
    bytes_model: str = "boundary",
) -> ModuleAnalysis:
    comps = _split_computations(text)
    mult = _multiplicities(text, comps)
    out = ModuleAnalysis()

    coll_re = re.compile(
        r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")

    for name, lines in comps.items():
        m_comp = mult.get(name, 0)
        if m_comp == 0:
            continue
        # local shape table: instruction name -> type text
        shapes: Dict[str, str] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                shapes[dm.group(1)] = dm.group(2)

        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            iname, itype, opcode = dm.group(1), dm.group(2), dm.group(3)

            if count_collectives and opcode in _COLLECTIVES:
                if "-done(" in line:
                    continue
                om = coll_re.search(line)
                if om:
                    gm = _GROUPS_RE.search(line)
                    if gm:
                        gsize = int(gm.group(2))
                    else:
                        gl = _GROUPS_LIST_RE.search(line)
                        gsize = len(gl.group(1).split(",")) if gl else 1
                    out.collectives.append(CollectiveOp(
                        kind=opcode, result_bytes=_shape_bytes(itype),
                        group_size=gsize, computation=name,
                        multiplicity=m_comp))

            if count_flops and opcode == "dot":
                fm = re.search(r"dot\((?:%?([\w.\-]+))\s*,", line)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if fm and cm and fm.group(1) in shapes:
                    lhs_dims = _shape_dims(shapes[fm.group(1)])
                    contracted = 1
                    if cm.group(1):
                        for d in cm.group(1).split(","):
                            di = int(d)
                            if di < len(lhs_dims):
                                contracted *= lhs_dims[di]
                    result_elems = 1
                    for d in _shape_dims(itype):
                        result_elems *= d
                    out.dot_flops += 2.0 * result_elems * contracted * m_comp

            if count_bytes and bytes_model == "major":
                b = 0.0
                sb = 0.0
                if itype.startswith("pred["):
                    # 1-byte masks: regenerated from iota in-register on
                    # TPU (never HBM-resident) — a CPU-lowering artifact
                    continue
                if opcode in ("dynamic-slice",):
                    # read of the sliced window; the write side is
                    # elided on TPU (scan-input slices alias/fuse into
                    # their consumers, which are counted separately)
                    b = 1.0 * _shape_bytes(itype)
                    if _is_score_block(itype) or _is_attn_accum(itype):
                        sb += b
                elif opcode == "dynamic-update-slice":
                    om = re.search(r"dynamic-update-slice\(([^)]*)\)", line)
                    if om:
                        ops_ = [o.strip().lstrip("%")
                                for o in om.group(1).split(",")]
                        if len(ops_) >= 2 and ops_[1] in shapes:
                            b = 2.0 * _shape_bytes(shapes[ops_[1]])
                            if (_is_score_block(shapes[ops_[1]])
                                    or _is_attn_accum(shapes[ops_[1]])):
                                sb += b
                elif opcode in _MAJOR_RESULT_ONLY:
                    b = float(_shape_bytes(itype))
                    if _is_score_block(itype):
                        sb += b
                    om = re.search(re.escape(opcode) + r"\(([^)]*)\)", line)
                    if om and opcode.startswith("reduce"):
                        for operand in om.group(1).split(","):
                            oname = operand.strip().lstrip("%")
                            if oname in shapes:
                                b += _shape_bytes(shapes[oname])
                                if _is_score_block(shapes[oname]):
                                    sb += _shape_bytes(shapes[oname])
                                break  # first (data) operand only
                elif opcode in _MAJOR_READ_WRITE:
                    b = float(_shape_bytes(itype))
                    if _is_score_block(itype):
                        sb += b
                    om = re.search(re.escape(opcode) + r"\(([^)]*)\)", line)
                    if om:
                        for operand in om.group(1).split(","):
                            oname = operand.strip().lstrip("%")
                            if oname in shapes:
                                b += _shape_bytes(shapes[oname])
                                if _is_score_block(shapes[oname]):
                                    sb += _shape_bytes(shapes[oname])
                elif opcode in _COLLECTIVES:
                    b = 2.0 * _shape_bytes(itype)  # HBM in + out
                out.hbm_bytes += b * m_comp
                out.score_bytes += sb * m_comp
                continue

            if count_bytes and opcode not in _FREE_OPS:
                if opcode == "dynamic-slice":
                    # reads+writes only the sliced window, not the operand
                    out.hbm_bytes += 2.0 * _shape_bytes(itype) * m_comp
                    continue
                if opcode == "dynamic-update-slice":
                    # in-place update: traffic ≈ read+write of the update
                    om = re.search(r"dynamic-update-slice\(([^)]*)\)", line)
                    upd = 0
                    if om:
                        ops_ = [o.strip().lstrip("%")
                                for o in om.group(1).split(",")]
                        if len(ops_) >= 2 and ops_[1] in shapes:
                            upd = _shape_bytes(shapes[ops_[1]])
                    out.hbm_bytes += 2.0 * upd * m_comp
                    continue
                b = _shape_bytes(itype)
                om = re.search(re.escape(opcode) + r"\(([^)]*)\)", line)
                if om:
                    for operand in om.group(1).split(","):
                        oname = operand.strip().lstrip("%")
                        if oname in shapes:
                            b += _shape_bytes(shapes[oname])
                out.hbm_bytes += float(b) * m_comp
    return out


# backwards-compatible collective-only entry point
def parse_collectives(text: str) -> ModuleAnalysis:
    return analyze_module(text, count_flops=False, count_bytes=False)
