"""Logical-axis sharding rules (GSPMD / pjit distribution layer).

Every parameter and activation in the framework is annotated with *logical*
axis names ("embed", "heads", "ffn", ...). A :class:`Rules` table maps
logical names to physical mesh axes; :func:`logical_spec` resolves a tuple
of logical names into a ``PartitionSpec``. This indirection is what lets
one model definition run on the single-pod (data=16, model=16) mesh, the
multi-pod (pod=2, data=16, model=16) mesh, smoke-test meshes, and a single
CPU device without touching model code — the MaxText/"logical axis rules"
pattern.

Default physical mapping (see DESIGN.md §5):

===============  =======================  =====================================
logical axis     physical axes            carried by
===============  =======================  =====================================
batch            ("pod", "data")          activations' batch dim (DP)
fsdp             ("pod", "data")          params' d_model dim (ZeRO-3 / FSDP)
vocab            "model"                  embedding + logits (TP)
heads            "model"                  q heads (TP) — if divisible
kv_heads         "model"                  kv heads (TP) — if divisible
head_dim         None | "model"           per-arch: "head_dim" shard mode
ffn              "model"                  MLP hidden (TP)
experts          "model"                  MoE experts (EP)
d_inner          "model"                  Mamba inner dim (TP)
seq              None                     sequence (dense compute)
seq_sp           "model"                  sequence-parallel residual stream
state_k/state_v  None                     the paper's k×k state dims (tiny)
===============  =======================  =====================================

All rules degrade gracefully: a physical axis absent from the mesh resolves
to ``None`` (replicated), and a logical dim whose size does not divide the
mesh axis falls back to replicated rather than failing to compile.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "vocab": "model",
    "heads": "model",            # flattened h*dh projection dims (params)
    "kv_heads_flat": "model",    # flattened hkv*dh projection dims
    "kv_heads": "model",         # activation Hkv dim (uneven allowed)
    "kv_heads_state": "model",   # decode-state Hkv dim (MUST divide —
                                 # jit argument shardings cannot pad; the
                                 # divisibility fallback drops to None and
                                 # head_dim_state takes the model axis)
    "group": "model",            # activation GQA group dim (uneven allowed)
    "heads_lin": "model",        # linear-backend flat head dim (uneven ok)
    "heads_state": "model",      # matrix-state head dim (must divide)
    "head_dim": None,
    "head_dim_state": "model",   # KV-cache head_dim (decode fallback TP)
    "ffn": "model",
    "experts": "model",
    "d_inner": "model",
    "conv_dim": "model",
    "seq": None,
    "seq_sp": "model",
    "state_k": None,
    "state_v": None,
    "embed": None,        # activations' d_model dim (replicated; TP is on
                          # the contracting param dims)
    "layers": None,       # stacked scan-over-layers leading dim
    "img_tokens": None,
}

# Logical axes that may shard unevenly (GSPMD pads): activation head dims
# where the head count need not divide the mesh — e.g. 8 kv heads on a
# 16-way model axis run at 2× attention-core waste rather than 16×
# replication. Parameter dims are never allowed to shard unevenly.
UNEVEN_OK = {"kv_heads", "group", "heads_lin"}


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical→physical axis mapping, specialised to a concrete mesh."""

    table: Dict[str, Axis]
    mesh_axes: Tuple[str, ...]
    mesh_shape: Dict[str, int]

    @classmethod
    def for_mesh(cls, mesh: Mesh, overrides: Optional[Dict[str, Axis]] = None
                 ) -> "Rules":
        table = dict(DEFAULT_RULES)
        if overrides:
            table.update(overrides)
        return cls(
            table=table,
            mesh_axes=tuple(mesh.axis_names),
            mesh_shape={a: int(s) for a, s in
                        zip(mesh.axis_names, mesh.devices.shape)},
        )

    @classmethod
    def null(cls) -> "Rules":
        """Rules for un-meshed (single device) execution: everything
        replicated. Used by smoke tests and the QA reproduction."""
        return cls(table={}, mesh_axes=(), mesh_shape={})

    # -- resolution ----------------------------------------------------------

    def axis_size(self, name: str) -> int:
        return self.mesh_shape.get(name, 1)

    @property
    def model_size(self) -> int:
        return self.axis_size("model")

    @property
    def data_size(self) -> int:
        return self.axis_size("data") * self.axis_size("pod")

    def _resolve_axis(self, logical: Optional[str], dim_size: Optional[int]
                      ) -> Axis:
        if logical is None:
            return None
        phys = self.table.get(logical, None)
        if phys is None:
            return None
        if isinstance(phys, str):
            phys = (phys,)
        # keep only axes present in the mesh
        phys = tuple(a for a in phys if a in self.mesh_axes)
        if not phys:
            return None
        if dim_size is not None and logical not in UNEVEN_OK:
            total = 1
            for a in phys:
                total *= self.mesh_shape[a]
            if dim_size % total != 0:
                # divisibility fallback: drop axes from the left until the
                # remaining product divides (pod first, then data).
                while phys and dim_size % _prod(self.mesh_shape, phys) != 0:
                    phys = phys[1:]
                if not phys:
                    return None
        return phys if len(phys) > 1 else phys[0]

    def spec(self, *logical: Optional[str],
             shape: Optional[Sequence[int]] = None) -> P:
        """Resolve logical names (one per array dim) to a PartitionSpec.

        ``shape``, when given, enables the divisibility fallback per dim.
        """
        out = []
        for i, name in enumerate(logical):
            size = None if shape is None else shape[i]
            out.append(self._resolve_axis(name, size))
        # PartitionSpec forbids using one mesh axis twice; detect + drop.
        seen = set()
        cleaned = []
        for ax in out:
            axes = (ax,) if isinstance(ax, str) else (ax or ())
            if any(a in seen for a in axes):
                cleaned.append(None)
                continue
            seen.update(axes)
            cleaned.append(ax)
        return P(*cleaned)

    def sharding(self, mesh: Mesh, *logical: Optional[str],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(mesh, self.spec(*logical, shape=shape))


def _prod(shape: Dict[str, int], axes: Tuple[str, ...]) -> int:
    total = 1
    for a in axes:
        total *= shape[a]
    return total


# ---------------------------------------------------------------------------
# Tree-level helpers
# ---------------------------------------------------------------------------

def is_logical_spec(x) -> bool:
    """A tuple of logical axis names (str | None) — NOT a NamedTuple
    (AttnState etc. are tuples too; they must be descended into)."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(e is None or isinstance(e, str) for e in x))


def tree_specs(logical_tree, rules: Rules, shape_tree=None):
    """Map a pytree of logical-name-tuples to a pytree of PartitionSpecs.

    ``logical_tree`` leaves are tuples of logical axis names (or None).
    ``shape_tree`` (optional, matching structure) provides shapes for the
    divisibility fallback.
    """
    if shape_tree is None:
        return jax.tree.map(
            lambda names: rules.spec(*names),
            logical_tree, is_leaf=is_logical_spec)
    return jax.tree.map(
        lambda names, shp: rules.spec(*names, shape=shp),
        logical_tree, shape_tree, is_leaf=is_logical_spec)


def constrain(x, rules: Rules, *logical: Optional[str]):
    """`with_sharding_constraint` in logical names; no-op off-mesh."""
    if not rules.mesh_axes:
        return x
    return jax.lax.with_sharding_constraint(
        x, rules.spec(*logical, shape=x.shape)
    )
