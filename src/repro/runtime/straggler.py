"""Straggler / hang detection from step-time telemetry.

At thousand-node scale the common failure modes are (a) a chip running
slow (thermal, ECC retry storms) and (b) a hung collective. Both show up
first in the step-time series. The detector keeps an EWMA and flags steps
exceeding ``threshold ×`` the smoothed time; a run of consecutive flags
triggers the mitigation callback (at real scale: snapshot + re-mesh
around the slow host — here, the callback is injected by tests and the
training loop records the event).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.2            # EWMA smoothing
    threshold: float = 2.5        # step slower than this × EWMA → flag
    patience: int = 3             # consecutive flags → mitigation
    warmup_steps: int = 2         # ignore compile-dominated first steps
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    ewma: Optional[float] = None
    consecutive: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)
    _seen: int = 0
    _t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        self.observe(step, dt)
        return dt

    def observe(self, step: int, dt: float) -> bool:
        """Feed one step time; returns True if the step was flagged."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return False
        if self.ewma is None:
            self.ewma = dt
            return False
        flagged = dt > self.threshold * self.ewma
        if flagged:
            self.consecutive += 1
            self.events.append(
                {"step": step, "dt": dt, "ewma": self.ewma})
            if self.consecutive >= self.patience and self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
                self.consecutive = 0
        else:
            self.consecutive = 0
            # only update the baseline with healthy steps so a slow
            # patch cannot normalise itself away
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return flagged
