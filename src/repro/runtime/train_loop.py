"""Fault-tolerant training loop.

Responsibilities beyond "call the step in a loop":

* **auto-resume** — on construction, restore the newest checkpoint if one
  exists (params + optimizer state + data-iterator position), so a
  preempted/killed job relaunches into the exact step it lost.
* **periodic + preemption checkpointing** — async saves every
  ``ckpt_every`` steps; ``request_preemption()`` (wired to SIGTERM by the
  launcher) forces a synchronous save at the next step boundary, the
  behaviour TPU maintenance events require.
* **failure injection** — ``fail_at_step`` raises mid-run (after the
  optimizer update, before the checkpoint), letting tests prove that a
  crash + relaunch reproduces the uninterrupted loss curve bit-exactly.
* **straggler telemetry** — every step time feeds the EWMA detector.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerDetector

log = logging.getLogger(__name__)


class InjectedFailure(RuntimeError):
    """Raised by the failure-injection hook (tests / chaos drills)."""


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    log_every: int = 10
    fail_at_step: Optional[int] = None   # failure injection
    async_ckpt: bool = True


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable,                   # (params, opt, batch) -> ...
        params: Any,
        opt_state: Any,
        dataset: Any,                        # has .batch_at(step)
        config: TrainLoopConfig,
        put_batch: Optional[Callable] = None,  # host batch -> device batch
    ):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.dataset = dataset
        self.config = config
        self.put_batch = put_batch or (lambda b: b)
        self.step = 0
        self.metrics_history: List[Dict[str, float]] = []
        self.detector = StragglerDetector()
        self._preempted = False

        self.ckpt: Optional[CheckpointManager] = None
        if config.ckpt_dir:
            self.ckpt = CheckpointManager(config.ckpt_dir, keep=config.keep)
            if self.ckpt.has_checkpoint():
                state = {"params": self.params, "opt": self.opt_state}
                restored, extra, step = self.ckpt.restore(state)
                self.params = restored["params"]
                self.opt_state = restored["opt"]
                self.step = int(extra.get("step", step))
                log.info("auto-resumed from step %d", self.step)

    # -- external controls ---------------------------------------------------

    def request_preemption(self) -> None:
        """SIGTERM handler target: checkpoint at the next boundary."""
        self._preempted = True

    # -- main loop -------------------------------------------------------------

    def run(self) -> Dict[str, Any]:
        cfg = self.config
        while self.step < cfg.total_steps:
            batch = self.put_batch(self.dataset.batch_at(self.step))
            self.detector.start()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = self.detector.stop(self.step)
            self.step += 1

            host = {k: float(np.asarray(v)) for k, v in metrics.items()}
            host["step_time"] = dt
            self.metrics_history.append(host)
            if self.step % cfg.log_every == 0:
                log.info("step %d loss %.4f (%.0f ms)",
                         self.step, host.get("loss", float("nan")),
                         dt * 1e3)

            want_ckpt = self.ckpt and (
                self.step % cfg.ckpt_every == 0
                or self.step == cfg.total_steps
                or self._preempted)
            if want_ckpt:
                self._save(blocking=self._preempted
                           or self.step == cfg.total_steps)
            if self._preempted:
                log.warning("preemption checkpoint at step %d", self.step)
                break
            if cfg.fail_at_step is not None and self.step == cfg.fail_at_step:
                raise InjectedFailure(f"injected failure at {self.step}")
        if self.ckpt:
            self.ckpt.wait()
        return {
            "params": self.params,
            "opt_state": self.opt_state,
            "step": self.step,
            "metrics": self.metrics_history,
            "straggler_events": self.detector.events,
        }

    def _save(self, blocking: bool) -> None:
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"step": self.step},
            blocking=blocking or not self.config.async_ckpt)
