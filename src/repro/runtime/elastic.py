"""Elastic re-mesh planning: map a surviving chip count to a mesh shape.

When nodes fail, the job restarts on the surviving topology. The planner
keeps the model axis fixed (TP degree is baked into layouts and must
divide head/ffn dims) and shrinks the data axis — DP degree is the
elastic dimension. Checkpoints restore via
:func:`repro.checkpoint.elastic.restore_on_mesh`; global batch is held
constant by raising gradient-accumulation steps, preserving training
semantics across the re-mesh (tested in tests/test_runtime_elastic.py).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple


def plan_mesh_shape(
    n_chips: int,
    *,
    model: int = 16,
    prefer_pods: Optional[int] = None,
) -> Dict[str, int]:
    """Largest (pod, data, model) grid fitting ``n_chips`` with the given
    TP degree. Returns {"pod": P, "data": D, "model": model}."""
    if n_chips < model:
        raise ValueError(f"{n_chips} chips cannot host model={model} TP")
    slots = n_chips // model
    if prefer_pods and slots % prefer_pods == 0:
        return {"pod": prefer_pods, "data": slots // prefer_pods,
                "model": model}
    return {"pod": 1, "data": slots, "model": model}


def accum_for_batch(global_batch: int, data_parallel: int,
                    per_device_batch: int = 1) -> Tuple[int, int]:
    """(microbatch per step, accumulation steps) that keep the global
    batch constant after DP shrinks."""
    per_step = data_parallel * per_device_batch
    if global_batch % per_step != 0:
        # fall back to the largest divisor ≤ per_step
        while global_batch % per_step != 0:
            per_step -= 1
    return per_step, global_batch // per_step
