"""Jitted step builders: train / eval / prefill / decode.

Each builder closes over (cfg, rules, optimizer) and returns a pure
function plus the sharding trees the launcher needs for ``jax.jit``'s
in_shardings/out_shardings. All distribution is expressed through
logical-axis PartitionSpecs — the same step lowers on a CPU smoke mesh,
the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.optim import Optimizer, global_norm
from repro.optim.accumulate import GradAccumulator
from repro.sharding import Rules

Array = jax.Array


def make_train_step(
    cfg: ModelConfig,
    rules: Rules,
    optimizer: Optimizer,
    *,
    n_micro: int = 1,
    grad_compress: bool = False,
) -> Callable:
    """(params, opt_state, batch) → (params, opt_state, metrics).

    ``grad_compress``: cast grads to bf16 before the data-parallel
    reduction (with fp32 re-expansion before Adam) — halves inter-pod
    gradient bytes; error feedback is handled by the loop when enabled.
    """
    accum = GradAccumulator(n_micro)

    def loss_fn(params, batch):
        loss, metrics = lm.lm_loss(params, batch, cfg, rules)
        return loss, metrics

    def step(params, opt_state, batch):
        loss, metrics, grads = accum.run(loss_fn, params, batch)
        if grad_compress:
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return step


def make_eval_step(cfg: ModelConfig, rules: Rules) -> Callable:
    def step(params, batch):
        loss, metrics = lm.lm_loss(params, batch, cfg, rules)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return metrics
    return step


def make_prefill_step(cfg: ModelConfig, rules: Rules) -> Callable:
    def step(params, tokens, memory=None):
        return lm.prefill(params, tokens, cfg, rules, memory=memory)
    return step


def make_decode_step(cfg: ModelConfig, rules: Rules) -> Callable:
    def step(params, state, token, pos):
        return lm.decode_step(params, state, token, pos, cfg, rules)
    return step


# ---------------------------------------------------------------------------
# sharding trees for jit
# ---------------------------------------------------------------------------

def train_state_specs(cfg: ModelConfig, rules: Rules):
    """(param specs, opt-state specs, batch specs) as logical names."""
    from repro.optim.adamw import opt_state_specs
    pspecs = lm.param_specs(cfg)
    ospecs = opt_state_specs(pspecs)
    bspecs: Dict[str, tuple] = {
        "tokens": ("batch", None),
        "labels": ("batch", None),
    }
    if cfg.n_img_tokens:
        bspecs["memory"] = ("batch", None, "embed")
    return pspecs, ospecs, bspecs
