"""Distributed runtime: step builders, fault-tolerant training loop,
straggler detection, elastic re-mesh planning."""

from repro.runtime.steps import (  # noqa: F401
    make_train_step, make_prefill_step, make_decode_step, make_eval_step,
)
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig  # noqa: F401
from repro.runtime.straggler import StragglerDetector  # noqa: F401
from repro.runtime.elastic import plan_mesh_shape  # noqa: F401
