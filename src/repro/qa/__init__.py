"""The paper's own experiment (§5): cloze QA with GRU encoders and the
four attention variants (none | linear | gated_linear | softmax)."""

from repro.qa.model import QAModel  # noqa: F401
from repro.qa.train import train_qa, TrainResult  # noqa: F401
