"""The paper's QA architecture (§5), faithful to the text:

* a single-layer GRU encodes the document (hidden size k = 100),
* a SEPARATE single-layer GRU encodes the query (footnote 3: unlike
  Hermann et al.'s no-attention baseline, document and query encoders
  are independent so the document representation is query-agnostic),
* word embeddings of size 100, ADAM training,
* four attention variants over the document states H (B, n, k):

    none          answer from [h_last; q] only
    linear        R(D,Q) = HᵀH q = C q          (paper §3)
    gated_linear  C = Σ f fᵀ, f = σ(Wh+b) ⊙ h   (paper §4, α=β=1)
    softmax       R(D,Q) = Hᵀ softmax(Hq)       (paper §2 baseline)

The linear variants store ONLY the k×k matrix C per document
(``encode_document`` → ``DocumentState``) — the fixed-size representation
— and answer queries in O(k²) via ``lookup`` (the paper's fast lookup).
The answer head scores the R(D,Q) representation against entity
embeddings (cloze over anonymised entities).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_qa import QAConfig
from repro.core.linear_attention import encode_document, lookup
from repro.core.gated import paper_gate
from repro.core.softmax_attention import softmax_lookup
from repro.qa.gru import gru_params, gru_scan

Array = jax.Array
Params = Dict[str, Array]

ATTENTION_VARIANTS = ("none", "linear", "gated_linear", "softmax",
                      "second_order")


class QAModel:
    def __init__(self, cfg: QAConfig):
        assert cfg.attention in ATTENTION_VARIANTS
        self.cfg = cfg

    # -- params ----------------------------------------------------------------

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        p: Params = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size,
                                                cfg.embed_dim)) * 0.1),
            "doc_gru": gru_params(ks[1], cfg.embed_dim, cfg.hidden),
            "query_gru": gru_params(ks[2], cfg.embed_dim, cfg.hidden),
            "w_out": (jax.random.normal(ks[3], (2 * cfg.hidden,
                                                cfg.hidden)) * 0.05),
            "b_out": jnp.zeros((cfg.hidden,)),
            "ans_embed": (jax.random.normal(ks[4], (cfg.n_entities,
                                                    cfg.hidden)) * 0.1),
        }
        if cfg.attention == "gated_linear":
            # the paper's gate f = σ(W h + b) ⊙ h
            p["w_gate"] = (jax.random.normal(ks[5], (cfg.hidden,
                                                     cfg.hidden)) * 0.05)
            p["b_gate"] = jnp.zeros((cfg.hidden,))
        if cfg.attention == "second_order":
            # the paper's §6 proposal: C and h updates interleaved
            from repro.core.second_order import second_order_params
            p["so"] = second_order_params(ks[6], cfg.embed_dim,
                                          cfg.hidden)
            del p["doc_gru"]
        return p

    # -- document encoding (the paper's "encode once") -------------------------

    def encode_doc(self, p: Params, doc: Array) -> Tuple[Array, Array]:
        """doc: (B, n) → (H (B, n, k) or C (B, k, k), h_last (B, k)).

        For the linear variants the n×k states collapse into the k×k
        fixed-size representation; softmax must keep all of H (the
        paper's Table-1 memory row, measured in benchmarks/table1.py).
        """
        emb = jnp.take(p["embed"], doc, axis=0)
        att = self.cfg.attention
        if att == "second_order":
            from repro.core.second_order import second_order_scan
            _, h_last, c = second_order_scan(p["so"], emb)
            return c, h_last
        hs, h_last = gru_scan(p["doc_gru"], emb)
        if att == "none":
            return h_last, h_last          # nothing else retained
        if att == "linear":
            return encode_document(hs), h_last
        if att == "gated_linear":
            f = paper_gate(hs, p["w_gate"], p["b_gate"])
            return encode_document(f), h_last
        return hs, h_last                  # softmax keeps H

    def encode_query(self, p: Params, query: Array) -> Array:
        emb = jnp.take(p["embed"], query, axis=0)
        _, q = gru_scan(p["query_gru"], emb)
        return q

    # -- lookup + answer --------------------------------------------------------

    def answer_logits(self, p: Params, doc_repr: Array, h_last: Array,
                      q: Array) -> Array:
        att = self.cfg.attention
        if att == "none":
            r = h_last
        elif att in ("linear", "gated_linear", "second_order"):
            r = lookup(doc_repr, q)        # O(k²) — the paper's claim
            r = r / (jnp.linalg.norm(r, axis=-1, keepdims=True) + 1e-6) \
                * jnp.sqrt(jnp.float32(self.cfg.hidden))
        else:
            r = softmax_lookup(doc_repr, q)
        feats = jnp.concatenate([r, q], axis=-1)
        hidden = jnp.tanh(feats @ p["w_out"] + p["b_out"])
        return hidden @ p["ans_embed"].T

    def loss_and_acc(self, p: Params, batch) -> Tuple[Array, Array]:
        doc_repr, h_last = self.encode_doc(p, batch.doc)
        q = self.encode_query(p, batch.query)
        logits = self.answer_logits(p, doc_repr, h_last, q)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(
            logp, batch.answer[:, None], axis=-1).mean()
        acc = jnp.mean(jnp.argmax(logits, -1) == batch.answer)
        return nll, acc
