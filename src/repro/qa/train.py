"""Training driver for the paper's QA experiment (Figure 1)."""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_qa import QAConfig
from repro.data.cloze import ClozeTask
from repro.optim import adam
from repro.qa.model import QAModel


@dataclasses.dataclass
class TrainResult:
    attention: str
    steps: List[int]
    val_acc: List[float]
    val_loss: List[float]

    @property
    def final_acc(self) -> float:
        return self.val_acc[-1]

    @property
    def best_acc(self) -> float:
        return max(self.val_acc)

    def steps_to_acc(self, target: float) -> int:
        """First step at which validation accuracy ≥ target (-1 if never)
        — the convergence-speed claim of Figure 1."""
        for s, a in zip(self.steps, self.val_acc):
            if a >= target:
                return s
        return -1


def train_qa(
    attention: str,
    *,
    steps: int = 400,
    eval_every: int = 40,
    seed: int = 0,
    cfg: QAConfig = None,
    task: ClozeTask = None,
) -> TrainResult:
    cfg = cfg or QAConfig(attention=attention)
    cfg = dataclasses.replace(cfg, attention=attention)
    task = task or ClozeTask(seed=seed + 1)

    model = QAModel(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    optimizer = adam(cfg.lr)
    opt_state = optimizer.init(params)

    @jax.jit
    def step_fn(params, opt_state, doc, query, answer):
        from repro.data.cloze import ClozeBatch
        batch = ClozeBatch(doc=doc, query=query, answer=answer)

        def loss_fn(p):
            loss, acc = model.loss_and_acc(p, batch)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss, acc

    @jax.jit
    def eval_fn(params, doc, query, answer):
        from repro.data.cloze import ClozeBatch
        return model.loss_and_acc(
            params, ClozeBatch(doc=doc, query=query, answer=answer))

    val = task.batch(256, step=10_000_000)  # held-out seed region
    result = TrainResult(attention=attention, steps=[], val_acc=[],
                         val_loss=[])
    for i in range(steps):
        b = task.batch(cfg.batch_size, step=i)
        params, opt_state, loss, acc = step_fn(
            params, opt_state, b.doc, b.query, b.answer)
        if (i + 1) % eval_every == 0 or i == 0:
            vloss, vacc = eval_fn(params, val.doc, val.query, val.answer)
            result.steps.append(i + 1)
            result.val_acc.append(float(vacc))
            result.val_loss.append(float(vloss))
    return result


def run_figure1(steps: int = 400, seed: int = 0) -> Dict[str, TrainResult]:
    """Train all four variants on the same data (the Figure-1 sweep)."""
    out = {}
    for att in ("none", "linear", "gated_linear", "softmax"):
        out[att] = train_qa(att, steps=steps, seed=seed)
    return out
