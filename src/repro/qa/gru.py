"""Single-layer GRU in pure JAX (the paper's encoder, §5)."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Array]


def gru_params(key, d_in: int, d_hidden: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_i = 1.0 / (d_in ** 0.5)
    scale_h = 1.0 / (d_hidden ** 0.5)
    return {
        # gates: reset | update (stacked), candidate separate
        "w_i": (jax.random.normal(k1, (d_in, 3 * d_hidden)) * scale_i
                ).astype(dtype),
        "w_h": (jax.random.normal(k2, (d_hidden, 3 * d_hidden)) * scale_h
                ).astype(dtype),
        "b": jnp.zeros((3 * d_hidden,), dtype),
    }


def gru_cell(p: Params, h: Array, x: Array) -> Array:
    """h: (B, K); x: (B, D) → new h."""
    k = h.shape[-1]
    gi = x @ p["w_i"] + p["b"]
    gh = h @ p["w_h"]
    i_r, i_z, i_n = jnp.split(gi, 3, axis=-1)
    h_r, h_z, h_n = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    return (1.0 - z) * n + z * h


def gru_scan(p: Params, xs: Array, h0: Optional[Array] = None
             ) -> Tuple[Array, Array]:
    """xs: (B, T, D) → (hidden states (B, T, K), last state (B, K))."""
    b, t, _ = xs.shape
    k = p["w_h"].shape[0]
    h0 = jnp.zeros((b, k), xs.dtype) if h0 is None else h0

    def step(h, x):
        h = gru_cell(p, h, x)
        return h, h

    h_last, hs = jax.lax.scan(step, h0, jnp.moveaxis(xs, 1, 0))
    return jnp.moveaxis(hs, 0, 1), h_last
