"""Paper Table 1: complexity/memory comparison, measured.

  a) Query complexity   — wall-time per lookup: softmax O(nk) grows with
     n; linear O(k²) flat. Measured at the paper's n=750, k=100 and at
     4×/16× longer documents.
  b) Document compression — bytes of the stored representation: n×k vs
     k×k.
  c) Encoding overhead — C is one extra rank-k update stream: measured
     encode time ratio (the paper's (λ+1)/λ row).

Also reproduces the §5 speedup estimate: at n=750, k=100 an optimised
lookup should be ≈ n/k ≈ 7.5× faster; we report the measured ratio.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.paper_qa import PAPER_K, PAPER_N
from repro.core.linear_attention import encode_document, lookup
from repro.core.softmax_attention import (
    lookup_flops_linear, lookup_flops_softmax, memory_linear,
    memory_softmax, softmax_lookup)


def _time(fn, *args, iters=50) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(batch: int = 64, m_queries: int = 16) -> List[Dict]:
    key = jax.random.PRNGKey(0)
    k_dim = PAPER_K
    rows = []
    lin_lookup = jax.jit(lookup)
    soft_lookup = jax.jit(softmax_lookup)
    enc = jax.jit(encode_document)

    for n in (PAPER_N, 4 * PAPER_N, 16 * PAPER_N):
        h = jax.random.normal(key, (batch, n, k_dim))
        q = jax.random.normal(jax.random.fold_in(key, 1),
                              (batch, m_queries, k_dim))
        c = enc(h)

        t_lin = _time(lin_lookup, c, q)
        t_soft = _time(soft_lookup, h, q)
        t_enc_h = _time(lambda x: x + 0.0, h)   # baseline copy cost
        t_enc_c = _time(enc, h)

        rows.append({
            "n": n,
            "k": k_dim,
            "m": m_queries,
            "lookup_us_linear": t_lin * 1e6,
            "lookup_us_softmax": t_soft * 1e6,
            "speedup": t_soft / t_lin,
            "theory_flops_ratio": (
                lookup_flops_softmax(n, k_dim, m_queries)
                / lookup_flops_linear(k_dim, m_queries)),
            "mem_bytes_softmax": memory_softmax(n, k_dim),
            "mem_bytes_linear": memory_linear(k_dim),
            "mem_ratio": memory_softmax(n, k_dim) / memory_linear(k_dim),
            "encode_us": t_enc_c * 1e6,
            "encode_baseline_us": t_enc_h * 1e6,
        })
    return rows


def main() -> List[str]:
    out = ["table,n,k,m,us_linear,us_softmax,speedup,mem_ratio"]
    for r in run():
        out.append(
            f"table1,{r['n']},{r['k']},{r['m']},"
            f"{r['lookup_us_linear']:.1f},{r['lookup_us_softmax']:.1f},"
            f"{r['speedup']:.2f},{r['mem_ratio']:.0f}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
