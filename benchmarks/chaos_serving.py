"""Chaos/survival benchmark for the fault-tolerant serving engine.

The paper's fixed-size O(k²) state is what makes every recovery path
here a few-KB copy: preempting a request is one ``snapshot_state``,
retrying a NaN-poisoned request is one ``write_slot_state`` from its
last good checkpoint, and a quarantined slot costs nothing to abandon
(row masking freezes it). This benchmark drives the
:class:`repro.serving.lifecycle.FaultInjector` through four scenarios
and reports survival metrics into ``BENCH_serving.json`` (merged under
the ``"chaos"`` key — ``continuous_batching.py`` owns the rest of the
file):

* **baseline** — the fault-free run every chaos run is compared against;
* **nan_retry** — NaN injected into an occupied slot mid-run: the
  poisoned request must recover via ONE snapshot-retry and every
  request (injected one included) must finish bit-identical to the
  baseline, on linear, gated_linear and softmax;
* **preempt** — a saturated pool preempted by a high-priority arrival:
  all streams bit-identical to running alone;
* **overload** — 2× more work than the bounded queue admits, with
  degradation armed: the engine sheds per policy (queue never grows
  past ``max_queue``), everything submitted resolves to a completion,
  and goodput (ok-status tokens/s) is reported.

All claims are deterministic (logical clock + event-keyed injection),
so CI greps the claim CSV exactly.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving import DecodeEngine, FaultInjector
from repro.sharding import Rules

RULES = Rules.null()
N_SLOTS = 2
SEGMENT_LEN = 4
MAX_LEN = 96
BENCH_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_serving.json")

# long enough budgets that every slot is mid-request at injection
# events (a NaN landing on a freed slot is harmlessly overwritten)
PROMPT_LENS = (8, 11, 6, 9, 7, 10)
GEN_LENS = (10, 12, 9, 11, 8, 10)


def _workload(vocab_size: int):
    rng = np.random.default_rng(0)
    return [(rng.integers(0, vocab_size, size=pl,
                          dtype=np.int64).astype(np.int32), g)
            for pl, g in zip(PROMPT_LENS, GEN_LENS)]


def _engine(params, cfg, **kw):
    return DecodeEngine(params, cfg, RULES, n_slots=N_SLOTS,
                        segment_len=SEGMENT_LEN, max_len=MAX_LEN, **kw)


def _drain(eng, workload, **submit_kw):
    for p, g in workload:
        eng.submit(p, g, **submit_kw)
    t0 = time.perf_counter()
    comps = eng.run("continuous")
    return comps, time.perf_counter() - t0


def run() -> Dict:
    key = jax.random.PRNGKey(0)
    per_backend = []
    unaffected_ok = True
    nan_retry_ok = True
    for backend in ("linear", "gated_linear", "softmax"):
        cfg = dataclasses.replace(
            get_smoke_config("yi-34b").with_backend(backend),
            dtype="float32")
        params = lm.init_params(key, cfg)
        workload = _workload(cfg.vocab_size)

        base, _ = _drain(_engine(params, cfg), workload)

        # NaN into slot 0 at the first segment boundary; one retry
        eng = _engine(params, cfg, max_retries=1,
                      injector=FaultInjector(nan=((0, 0),)))
        chaos, _ = _drain(eng, workload)
        st = eng.stats
        injected_recovered = (st.quarantined == 1 and st.retries == 1
                              and st.failed == 0)
        all_identical = all(
            np.array_equal(a.tokens, b.tokens) and b.status == "ok"
            for a, b in zip(base, chaos))
        # "unaffected" = every request the fault did NOT hit; under a
        # successful retry the injected one is ALSO bit-identical, so
        # the stronger check subsumes both claims
        unaffected_ok &= all(
            np.array_equal(a.tokens, b.tokens)
            for a, b in zip(base, chaos) if b.retries == 0)
        nan_retry_ok &= injected_recovered and all_identical
        per_backend.append({
            "backend": backend,
            "quarantined": st.quarantined, "retries": st.retries,
            "failed": st.failed, "resumes": st.resumes,
            "finite_checks": st.finite_checks,
            "all_bit_identical": all_identical,
        })

    # -- preempt/resume under priority pressure (linear) ---------------
    cfg = dataclasses.replace(
        get_smoke_config("yi-34b").with_backend("linear"),
        dtype="float32")
    params = lm.init_params(key, cfg)
    workload = _workload(cfg.vocab_size)
    jobs = [(workload[0][0], 12, 0.0, 0), (workload[1][0], 12, 0.0, 0),
            (workload[2][0], 8, 6.0, 5)]
    solo = []
    for p, g, *_ in jobs:
        e = _engine(params, cfg)
        e.submit(p, g)
        solo.append(e.run()[0].tokens)
    eng = _engine(params, cfg)
    for p, g, arr, pri in jobs:
        eng.submit(p, g, arrival=arr, priority=pri)
    comps = eng.run("continuous")
    preempt_ok = (eng.stats.preemptions >= 1
                  and eng.stats.resumes == eng.stats.preemptions
                  and all(np.array_equal(c.tokens, s)
                          for c, s in zip(comps, solo)))
    preempt_stats = {"preemptions": eng.stats.preemptions,
                     "resumes": eng.stats.resumes,
                     "checkpoints": eng.stats.checkpoints}

    # -- 2x overload against a bounded queue + degradation -------------
    rng = np.random.default_rng(2)
    n_over = 4 * N_SLOTS                  # 2x what max_queue+slots hold
    max_queue = N_SLOTS
    eng = _engine(params, cfg, max_queue=max_queue,
                  shed_policy="reject_new", degrade_threshold=1.0)
    t0 = time.perf_counter()
    uids = [eng.submit(
        rng.integers(0, cfg.vocab_size, size=8,
                     dtype=np.int64).astype(np.int32), 8,
        arrival=float(i // N_SLOTS), priority=i % 2)
        for i in range(n_over)]
    over = eng.run("continuous")
    dt = time.perf_counter() - t0
    st = eng.stats
    ok_tokens = sum(len(c.tokens) for c in over if c.status == "ok")
    survival = {
        "submitted": n_over, "max_queue": max_queue,
        "completed_ok": sum(c.status == "ok" for c in over),
        "shed": st.shed, "deadline": st.deadline_evictions,
        "retried": st.retries, "failed": st.failed,
        "degrade_transitions": st.degrade_transitions,
        "goodput_tokens_per_s": ok_tokens / dt,
    }
    overload_ok = (len(over) == len(uids)        # every submit resolves
                   and st.shed > 0               # the bound actually bit
                   and survival["completed_ok"] + st.shed
                   + st.deadline_evictions + st.failed == n_over)

    claims = {
        "chaos_unaffected_bit_identical": unaffected_ok,
        "chaos_nan_retry_bit_identical": nan_retry_ok,
        "chaos_preempt_resume_bit_identical": preempt_ok,
        "chaos_overload_sheds_bounded": overload_ok,
    }
    return {
        "n_slots": N_SLOTS, "segment_len": SEGMENT_LEN,
        "nan_injection": per_backend,
        "preempt": preempt_stats,
        "overload": survival,
        "claims": claims,
    }


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


def run_durability() -> Dict:
    """Kill-and-recover benchmark: crash the engine at an event
    boundary, recover from checkpoint + journal, and measure that
    recovery is lossless, bit-identical and deterministic — then trace
    checkpoint size against ``max_len`` (the paper's fixed-size state
    means a linear-backend checkpoint is O(slots·k²) FLAT, while a
    softmax KV checkpoint grows with the decode window)."""
    import shutil
    import tempfile

    from repro.serving import (DecodeEngine, FleetEngine, InjectedCrash,
                               Journal, fleet_demo_config)

    key = jax.random.PRNGKey(0)
    scratch = tempfile.mkdtemp(prefix="chaos_durability_")
    per_backend = []
    zero_loss = True
    bit_identical = True
    replay_deterministic = True
    try:
        for backend in ("linear", "softmax", "mamba2"):
            cfg = fleet_demo_config(backend)
            params = lm.init_params(key, cfg)
            workload = _workload(cfg.vocab_size)

            base, _ = _drain(_engine(params, cfg), workload)
            base_toks = {c.uid: list(np.asarray(c.tokens)) for c in base}

            jp = os.path.join(scratch, f"{backend}.journal")
            cd = os.path.join(scratch, f"{backend}.ck")
            eng = _engine(params, cfg, journal=jp, checkpoint_dir=cd,
                          checkpoint_every=2,
                          injector=FaultInjector(crash=(3,)))
            for p, g in workload:
                eng.submit(p, g)
            try:
                eng.run("continuous")
                raise RuntimeError("injected crash did not fire")
            except InjectedCrash:
                pass

            def _recover():
                t0 = time.perf_counter()
                rec = DecodeEngine.recover(
                    params, cfg, RULES, journal=Journal(jp),
                    checkpoint_dir=cd, n_slots=N_SLOTS,
                    segment_len=SEGMENT_LEN, max_len=MAX_LEN)
                t_restore = time.perf_counter() - t0
                t0 = time.perf_counter()
                rec.run("continuous")
                return rec, t_restore, time.perf_counter() - t0

            rec1, t_restore, t_finish = _recover()
            rec2, _, _ = _recover()
            got1 = {c.uid: list(np.asarray(c.tokens))
                    for c in rec1.completions()}
            got2 = {c.uid: list(np.asarray(c.tokens))
                    for c in rec2.completions()}
            acks = [r for r in rec1.journal.records() if r["t"] == "ack"]
            b_zero_loss = (sorted(got1) == sorted(base_toks)
                           and sorted(r["uid"] for r in acks)
                           == sorted(base_toks))
            b_identical = got1 == base_toks
            b_replay = got1 == got2
            zero_loss &= b_zero_loss
            bit_identical &= b_identical
            replay_deterministic &= b_replay
            per_backend.append({
                "backend": backend,
                "requests": len(base_toks),
                "recovered": len(got1),
                "zero_loss": b_zero_loss,
                "bit_identical": b_identical,
                "replay_deterministic": b_replay,
                "restore_s": t_restore,
                "finish_s": t_finish,
                "journal_bytes": os.path.getsize(jp),
                "checkpoint_bytes": _dir_bytes(cd),
            })

        # -- checkpoint bytes vs decode window -------------------------
        curves = {}
        for backend in ("linear", "softmax"):
            cfg = fleet_demo_config(backend)
            params = lm.init_params(key, cfg)
            pts = []
            for max_len in (32, 64, 128):
                cd = os.path.join(scratch, f"curve.{backend}.{max_len}")
                eng = DecodeEngine(params, cfg, RULES, n_slots=N_SLOTS,
                                   segment_len=SEGMENT_LEN,
                                   max_len=max_len, checkpoint_dir=cd)
                for p, g in _workload(cfg.vocab_size)[:2]:
                    eng.submit(p, g)
                eng.step()
                eng.save_checkpoint()
                pts.append({"max_len": max_len,
                            "bytes": _dir_bytes(cd)})
            curves[backend] = pts
        lin = [p["bytes"] for p in curves["linear"]]
        sof = [p["bytes"] for p in curves["softmax"]]
        # "flat": quadrupling the window moves the linear checkpoint by
        # <10% (only host metadata), while softmax KV at least doubles
        linear_flat = lin[-1] <= lin[0] * 1.10
        softmax_grows = sof[-1] >= sof[0] * 2.0
    finally:
        shutil.rmtree(scratch, ignore_errors=True)

    claims = {
        "durability_zero_loss": zero_loss,
        "durability_bit_identical": bit_identical,
        "durability_replay_deterministic": replay_deterministic,
        "durability_ckpt_bytes_linear_flat": linear_flat,
        "durability_ckpt_bytes_softmax_grows": softmax_grows,
    }
    return {
        "n_slots": N_SLOTS, "segment_len": SEGMENT_LEN,
        "crash_event": 3, "checkpoint_every": 2,
        "kill_and_recover": per_backend,
        "checkpoint_bytes_vs_max_len": curves,
        "claims": claims,
    }


def main() -> List[str]:
    res = run()
    out = ["chaos,backend,quarantined,retries,failed,resumes,"
           "finite_checks,bit_identical"]
    for r in res["nan_injection"]:
        out.append(f"chaos,{r['backend']},{r['quarantined']},"
                   f"{r['retries']},{r['failed']},{r['resumes']},"
                   f"{r['finite_checks']},{r['all_bit_identical']}")
    s = res["overload"]
    out.append("chaos_overload,submitted,completed_ok,shed,failed,"
               "degrade_flips,goodput_tok_s")
    out.append(f"chaos_overload,{s['submitted']},{s['completed_ok']},"
               f"{s['shed']},{s['failed']},{s['degrade_transitions']},"
               f"{s['goodput_tokens_per_s']:.0f}")
    for name, ok in res["claims"].items():
        out.append(f"chaos_claim,{name},{'PASS' if ok else 'FAIL'}")

    dur = run_durability()
    out.append("durability,backend,requests,recovered,restore_s,"
               "finish_s,journal_bytes,checkpoint_bytes")
    for r in dur["kill_and_recover"]:
        out.append(f"durability,{r['backend']},{r['requests']},"
                   f"{r['recovered']},{r['restore_s']:.3f},"
                   f"{r['finish_s']:.3f},{r['journal_bytes']},"
                   f"{r['checkpoint_bytes']}")
    for backend, pts in dur["checkpoint_bytes_vs_max_len"].items():
        for p in pts:
            out.append(f"durability_ckpt_bytes,{backend},"
                       f"{p['max_len']},{p['bytes']}")
    for name, ok in dur["claims"].items():
        out.append(f"durability_claim,{name},{'PASS' if ok else 'FAIL'}")

    # merge under "chaos"/"durability" — continuous_batching.py owns
    # the rest of the file
    try:
        with open(BENCH_PATH) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError):
        bench = {}
    bench["chaos"] = res
    bench["durability"] = dur
    with open(BENCH_PATH, "w") as f:
        json.dump(bench, f, indent=2)
    return out


if __name__ == "__main__":
    print("\n".join(main()))
