"""Speculative lookahead vs plain continuous batching.

Speculative decoding converts sequential decode steps into windowed
verify passes: a draft proposes K tokens and ONE ``lm.decode_window``
launch per layer scores the whole (K+1)-token window, so at acceptance
rate ``a`` the target model runs ~(1 + a·K) tokens per windowed pass
instead of one token per sequential pass. The paper's fixed-size O(k²)
state is what makes the bookkeeping free-ish: committing an accepted
window is a masked select over k×k matrices, rewinding a rejected one
is a snapshot re-advance — no KV-cache replay.

Measured on the CPU smoke config, same engine, same workload,
bit-identical outputs (asserted):

* ``plain``        — continuous batching, one token per slot-step.
* ``spec_oracle``  — ReplayDraft replays the plain run's tokens: the
  HIGH-ACCEPTANCE synthetic mix (acceptance ≈ 1 until each request's
  final window). Claimed ≥ 1.3× aggregate tokens/s over plain.
* ``spec_ngram``   — NgramDraft (prompt-lookup): whatever acceptance the
  random-weight model's output regularity yields; reported, not gated.

Deterministic form of the claim for CI (wall clock flakes on shared
runners): a plain segment costs ``segment_len`` SEQUENTIAL model passes,
a speculative round costs ONE windowed pass (+1 per rewind), so
``spec_fewer_model_passes`` asserts the pass-count ratio ≥ 1.3 exactly.

Results land in ``BENCH_spec.json`` at the repo root.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving import DecodeEngine, NgramDraft, ReplayDraft
from repro.sharding import Rules

RULES = Rules.null()
N_SLOTS = 4
SEGMENT_LEN = 8
PROMPT_LEN = 8
GEN_LEN = 96
N_REQUESTS = 16
SPECULATE_K = 12
REPEATS = 3             # best-of, interleaved across modes
BENCH_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_spec.json")


def _workload(vocab_size: int):
    rng = np.random.default_rng(0)
    return [rng.integers(0, vocab_size, size=PROMPT_LEN,
                         dtype=np.int64).astype(np.int32)
            for _ in range(N_REQUESTS)]


def _run(engine: DecodeEngine, prompts, speculate_k: int, draft=None):
    engine.draft = draft
    engine.reset()
    for p in prompts:
        engine.submit(p, GEN_LEN, speculate_k=speculate_k)
    t0 = time.perf_counter()
    completions = engine.run("continuous")
    dt = time.perf_counter() - t0
    return completions, dt


def run() -> Dict:
    key = jax.random.PRNGKey(0)
    # fp32 on CPU (XLA emulates bf16 with converts around every op) and
    # greedy argmax margins far above window/step reassociation noise
    cfg = dataclasses.replace(
        get_smoke_config("yi-34b").with_backend("linear"),
        dtype="float32")
    params = lm.init_params(key, cfg)
    prompts = _workload(cfg.vocab_size)
    engine = DecodeEngine(
        params, cfg, RULES, n_slots=N_SLOTS, segment_len=SEGMENT_LEN,
        max_len=PROMPT_LEN + GEN_LEN + SPECULATE_K + 1)

    plain, _ = _run(engine, prompts, 0)
    oracle = ReplayDraft({ReplayDraft.key(p): c.tokens
                          for p, c in zip(prompts, plain)})
    ngram = NgramDraft()
    modes = {"plain": (0, None), "spec_oracle": (SPECULATE_K, oracle),
             "spec_ngram": (SPECULATE_K, ngram)}

    for k, d in modes.values():                      # compile all paths
        _run(engine, prompts, k, d)

    best: Dict[str, float] = {m: float("inf") for m in modes}
    stats: Dict[str, Dict] = {}
    for _ in range(REPEATS):
        for mode, (k, d) in modes.items():
            comps, dt = _run(engine, prompts, k, d)
            # the speculative bit-identity contract, enforced in the
            # exact binary CI runs
            for a, b in zip(plain, comps):
                assert a.uid == b.uid and np.array_equal(
                    a.tokens, b.tokens), \
                    f"{mode} diverged from plain greedy on {a.uid}"
            if dt < best[mode]:
                best[mode] = dt
            st = engine.stats
            stats[mode] = {
                "segments": st.segments,
                "spec_rounds": st.spec_rounds,
                "spec_rewinds": st.spec_rewinds,
                "spec_rewind_rounds": st.spec_rewind_rounds,
                "spec_rewind_dispatches": st.spec_rewind_dispatches,
                "acceptance_rate": st.acceptance_rate,
                "tokens_per_round": st.tokens_per_round,
            }

    total = sum(len(c.tokens) for c in plain)
    rows = []
    for mode in modes:
        # sequential model passes the device actually ran: segments ×
        # segment_len one-token steps, plus one windowed verify pass per
        # round and ONE batched varlen re-advance per rewinding round
        # (the per-slot rewind loop this replaced paid one pass per
        # rewinding slot)
        passes = (stats[mode]["segments"] * SEGMENT_LEN
                  + stats[mode]["spec_rounds"]
                  + stats[mode]["spec_rewind_dispatches"])
        rows.append({
            "mode": mode,
            "total_tokens": total,
            "tokens_per_s": total / best[mode],
            "model_passes": passes,
            **stats[mode],
        })
    by = {r["mode"]: r for r in rows}
    claims = {
        "outputs_bit_identical": True,    # asserted on every run above
        "acceptance_positive": by["spec_oracle"]["acceptance_rate"] > 0
        and by["spec_ngram"]["acceptance_rate"] > 0,
        # the acceptance bar: ≥1.3× aggregate tokens/s on the
        # high-acceptance mix
        "spec_1p3x_over_plain":
            by["spec_oracle"]["tokens_per_s"]
            >= 1.3 * by["plain"]["tokens_per_s"],
        # CI gate (robust under runner load): at least no slower
        "spec_at_least_plain":
            by["spec_oracle"]["tokens_per_s"]
            >= by["plain"]["tokens_per_s"],
        # deterministic form: ≥1.3× fewer sequential model passes
        "spec_fewer_model_passes":
            by["plain"]["model_passes"]
            >= 1.3 * by["spec_oracle"]["model_passes"],
        # batched rewind: every round with partial acceptors re-advances
        # ALL of them in exactly ONE decode_window_varlen dispatch (the
        # ngram mode reliably produces partial-acceptance rounds on
        # random weights; oracle rounds rewind at request tails)
        "rewind_single_dispatch_per_round": all(
            s["spec_rewind_dispatches"] == s["spec_rewind_rounds"]
            for s in stats.values()),
        "rewind_exercised": any(
            s["spec_rewinds"] > s["spec_rewind_dispatches"] > 0
            for s in stats.values()),
    }
    return {"n_slots": N_SLOTS, "segment_len": SEGMENT_LEN,
            "speculate_k": SPECULATE_K,
            "workload": {"n_requests": N_REQUESTS,
                         "prompt_len": PROMPT_LEN, "gen_len": GEN_LEN},
            "rows": rows, "claims": claims}


def main() -> List[str]:
    result = run()
    out = ["speculative,mode,tok_s,acceptance,rounds,rewinds,model_passes"]
    for r in result["rows"]:
        out.append(
            f"speculative,{r['mode']},{r['tokens_per_s']:.0f},"
            f"{r['acceptance_rate']:.2f},{r['spec_rounds']},"
            f"{r['spec_rewinds']},{r['model_passes']}")
    for name, ok in result["claims"].items():
        out.append(f"speculative_claim,{name},{'PASS' if ok else 'FAIL'}")
    with open(BENCH_PATH, "w") as f:
        json.dump(result, f, indent=2)
    return out


if __name__ == "__main__":
    print("\n".join(main()))
