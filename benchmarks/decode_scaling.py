"""Beyond-paper: the paper's Table-1 claim inside a full transformer.

Measures ONE full-model decode step (all layers) as a function of the
context length already consumed:

  softmax backend — KV-cache attention: O(context) per step
  linear backend  — k×k state lookup:   O(1) per step  (paper's claim)

Uses the yi-34b smoke config so the numbers are CPU-friendly; the shape
of the curves (flat vs linear growth), not their absolute values, is the
validated claim.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm
from repro.sharding import Rules

RULES = Rules.null()


def _time_step(fn, params, state, tok, pos, iters=20) -> float:
    logits, st = fn(params, state, tok, pos)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(iters):
        logits, st = fn(params, state, tok, pos)
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / iters


def run(contexts=(256, 1024, 4096)) -> List[Dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    for backend in ("softmax", "linear"):
        cfg = get_smoke_config("yi-34b").with_backend(backend)
        params = lm.init_params(key, cfg)

        @jax.jit
        def step(params, state, tok, pos, cfg=cfg):
            return lm.decode_step(params, state, tok, pos, cfg, RULES)

        for ctx in contexts:
            state = lm.init_decode_state(cfg, batch=4, max_len=ctx + 8)
            tok = jnp.zeros((4,), jnp.int32)
            t = _time_step(step, params, state, tok, jnp.int32(ctx))
            state_bytes = sum(x.nbytes for x in jax.tree.leaves(state))
            rows.append({"backend": backend, "context": ctx,
                         "us_per_step": t * 1e6,
                         "state_bytes": state_bytes})
    return rows


def main() -> List[str]:
    rows = run()
    out = ["decode_scaling,backend,context,us_per_step,state_bytes"]
    for r in rows:
        out.append(f"decode_scaling,{r['backend']},{r['context']},"
                   f"{r['us_per_step']:.0f},{r['state_bytes']}")
    # claim: linear flat (<2× across 16× context), softmax state grows
    lin = [r for r in rows if r["backend"] == "linear"]
    soft = [r for r in rows if r["backend"] == "softmax"]
    flat = lin[-1]["us_per_step"] < 3 * lin[0]["us_per_step"]
    state_const = lin[0]["state_bytes"] == lin[-1]["state_bytes"]
    kv_grows = soft[-1]["state_bytes"] > 10 * soft[0]["state_bytes"]
    out.append(f"decode_scaling_claim,linear_time_flat,"
               f"{'PASS' if flat else 'FAIL'}")
    out.append(f"decode_scaling_claim,linear_state_constant,"
               f"{'PASS' if state_const else 'FAIL'}")
    out.append(f"decode_scaling_claim,softmax_state_grows,"
               f"{'PASS' if kv_grows else 'FAIL'}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
