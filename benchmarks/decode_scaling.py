"""Beyond-paper: the paper's Table-1 claim inside a full transformer.

Two measurements on the yi-34b smoke config (CPU-friendly; curve SHAPES,
not absolute values, are the validated claims):

1. Per-step cost as a function of context already consumed:
     softmax backend — KV-cache attention: O(context) per step
     linear backend  — k×k state lookup:   O(1) per step  (paper's claim)

2. Generation-loop fusion (the serving hot path): the pre-fusion driver
   dispatched one jitted ``decode_step`` per token — per-token cost was
   dispatch- and HBM-round-trip-dominated. ``lm.generate`` runs the whole
   loop as ONE dispatch (``lax.scan`` + fused recurrent kernels), so we
   report tokens/s for both drivers and the implied per-token
   ``dispatch_overhead_us`` (time for W per-token dispatches minus the
   time for W fused steps, over W).

Drivers are compared as shipped: ``seed_loop`` is the pre-fusion
driver exactly as the seed ran it (bf16 smoke config, jnp recurrence,
one dispatch per token); ``fused`` is the engine's CPU configuration
(float32 — CPU XLA emulates bf16 with converts around every op — with
the auto kernel selection). ``loop`` re-times the per-token driver on
the engine config so ``dispatch_overhead_us`` isolates pure
dispatch/HBM-round-trip cost at equal numerics. Drivers are timed
interleaved with best-of-``REPEATS`` so OS load drift hits all of them
equally. Results also land in ``BENCH_decode.json`` at the repo root so
the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm
from repro.sharding import Rules

RULES = Rules.null()
GEN_STEPS = 64          # W: tokens generated per fused launch
REPEATS = 8             # best-of, interleaved across drivers
BENCH_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_decode.json")


def _time_step(fn, params, state, tok, pos, iters=20) -> float:
    logits, st = fn(params, state, tok, pos)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(iters):
        logits, st = fn(params, state, tok, pos)
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / iters


def _time_drivers(drivers):
    """``drivers``: list of zero-arg callables, each one full generation
    pass. Interleaved best-of-``REPEATS`` so load drift hits all drivers
    equally; a first untimed round absorbs compilation."""
    for d in drivers:
        d()
    best = [float("inf")] * len(drivers)
    for _ in range(REPEATS):
        for j, d in enumerate(drivers):
            t0 = time.perf_counter()
            d()
            best[j] = min(best[j], time.perf_counter() - t0)
    return best


def _loop_driver(step_fn, params, state, tok0, pos0, n_steps):
    """Per-token driver: one jitted dispatch per token, argmax feedback
    in Python — n_steps dispatches + host round trips (the seed's
    serve loop, verbatim)."""

    def drive():
        tok, st = tok0, state
        for i in range(n_steps):
            logits, st = step_fn(params, st, tok, jnp.int32(pos0 + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)

    return drive


def _fused_driver(gen_fn, params, state, tok0, pos0):
    """Fused driver: the whole generation is one lm.generate dispatch."""

    def drive():
        toks, _ = gen_fn(params, state, tok0, jnp.int32(pos0))
        jax.block_until_ready(toks)

    return drive


def run(contexts=(256, 1024, 4096)) -> List[Dict]:
    import dataclasses

    key = jax.random.PRNGKey(0)
    rows = []
    batch = 4
    for backend in ("softmax", "linear"):
        # the pre-fusion driver exactly as the seed shipped it: bf16
        # smoke config, jnp recurrence, one jitted dispatch per token
        cfg_seed = dataclasses.replace(
            get_smoke_config("yi-34b").with_backend(backend),
            decode_kernel="reference")
        # the engine's CPU configuration (fp32 — see docstring), plus
        # an equal-numerics per-token loop (dispatch-overhead control)
        # and the forced-Pallas path (interpret mode on CPU)
        cfg = dataclasses.replace(cfg_seed, dtype="float32",
                                  decode_kernel="auto")
        cfg_loop = dataclasses.replace(cfg, decode_kernel="reference")
        # forcing the Pallas kernels only means something for the linear
        # family; softmax has no fused decode kernel (config validation
        # now rejects the combination), so its "forced" driver is the
        # auto path it always effectively ran
        cfg_forced = dataclasses.replace(
            cfg, decode_kernel="fused" if backend != "softmax" else "auto")
        params = lm.init_params(key, cfg)

        @jax.jit
        def step_seed(params, state, tok, pos, cfg=cfg_seed):
            return lm.decode_step(params, state, tok, pos, cfg, RULES)

        @jax.jit
        def step(params, state, tok, pos, cfg=cfg_loop):
            return lm.decode_step(params, state, tok, pos, cfg, RULES)

        @jax.jit
        def gen(params, state, tok, pos, cfg=cfg):
            return lm.generate(params, state, tok, pos, GEN_STEPS, cfg,
                               RULES)

        @jax.jit
        def gen_forced(params, state, tok, pos, cfg=cfg_forced):
            return lm.generate(params, state, tok, pos, GEN_STEPS, cfg,
                               RULES)

        for ctx in contexts:
            state = lm.init_decode_state(cfg, batch=batch,
                                         max_len=ctx + GEN_STEPS + 8)
            # the seed driver gets the seed's own (bf16-cache) state —
            # its KV memory traffic must match what actually shipped
            state_seed = lm.init_decode_state(cfg_seed, batch=batch,
                                              max_len=ctx + GEN_STEPS + 8)
            tok = jnp.zeros((batch,), jnp.int32)
            t = _time_step(step, params, state, tok, jnp.int32(ctx))
            t_seed, t_loop, t_fused, t_forced = _time_drivers([
                _loop_driver(step_seed, params, state_seed, tok, ctx,
                             GEN_STEPS),
                _loop_driver(step, params, state, tok, ctx, GEN_STEPS),
                _fused_driver(gen, params, state, tok, ctx),
                _fused_driver(gen_forced, params, state, tok, ctx),
            ])
            state_bytes = sum(x.nbytes for x in jax.tree.leaves(state))
            rows.append({
                "backend": backend, "context": ctx,
                "us_per_step": t * 1e6,
                "state_bytes": state_bytes,
                "seed_loop_tokens_per_s": batch * GEN_STEPS / t_seed,
                "loop_tokens_per_s": batch * GEN_STEPS / t_loop,
                "fused_tokens_per_s": batch * GEN_STEPS / t_fused,
                "fused_interpret_tokens_per_s":
                    batch * GEN_STEPS / t_forced,
                "dispatch_overhead_us": (t_loop - t_fused) / GEN_STEPS
                                        * 1e6,
                "fused_speedup": t_seed / t_fused,
            })
    return rows


def main() -> List[str]:
    rows = run()
    out = ["decode_scaling,backend,context,us_per_step,state_bytes,"
           "seed_loop_tok_s,loop_tok_s,fused_tok_s,fused_interp_tok_s,"
           "dispatch_overhead_us,fused_speedup"]
    for r in rows:
        out.append(
            f"decode_scaling,{r['backend']},{r['context']},"
            f"{r['us_per_step']:.0f},{r['state_bytes']},"
            f"{r['seed_loop_tokens_per_s']:.0f},"
            f"{r['loop_tokens_per_s']:.0f},{r['fused_tokens_per_s']:.0f},"
            f"{r['fused_interpret_tokens_per_s']:.0f},"
            f"{r['dispatch_overhead_us']:.0f},{r['fused_speedup']:.1f}")
    # claims: linear flat in context, linear state constant, KV grows,
    # fused engine ≥5× the seed per-token driver at the longest context
    lin = [r for r in rows if r["backend"] == "linear"]
    soft = [r for r in rows if r["backend"] == "softmax"]
    flat = lin[-1]["us_per_step"] < 3 * lin[0]["us_per_step"]
    state_const = lin[0]["state_bytes"] == lin[-1]["state_bytes"]
    kv_grows = soft[-1]["state_bytes"] > 10 * soft[0]["state_bytes"]
    fused_fast = lin[-1]["fused_speedup"] >= 5.0
    claims = {
        "linear_time_flat": flat,
        "linear_state_constant": state_const,
        "softmax_state_grows": kv_grows,
        "fused_generate_5x": fused_fast,
    }
    for name, ok in claims.items():
        out.append(f"decode_scaling_claim,{name},"
                   f"{'PASS' if ok else 'FAIL'}")
    with open(BENCH_PATH, "w") as f:
        json.dump({"gen_steps": GEN_STEPS, "rows": rows,
                   "claims": claims}, f, indent=2)
    return out


if __name__ == "__main__":
    print("\n".join(main()))
