"""Render the §Dry-run / §Roofline tables from the dry-run artifacts."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../experiments/artifacts")

ARCH_ORDER = [
    "deepseek-moe-16b", "qwen3-moe-235b-a22b", "musicgen-large", "yi-34b",
    "internlm2-20b", "phi3-mini-3.8b", "qwen3-0.6b", "zamba2-7b",
    "rwkv6-1.6b", "llama-3.2-vision-90b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_artifacts() -> List[Dict]:
    arts = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        with open(path) as f:
            arts.append(json.load(f))
    return arts


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def _key(a):
    return (ARCH_ORDER.index(a["arch"]) if a["arch"] in ARCH_ORDER else 99,
            SHAPE_ORDER.index(a["shape"]) if a["shape"] in SHAPE_ORDER
            else 99, a["mesh"], a.get("backend", ""))


def roofline_table(arts: List[Dict], mesh: str = "single") -> List[str]:
    rows = [
        "| arch | shape | backend | t_comp | t_mem | t_mem(pallas) "
        "| t_coll | bound | bottleneck | 6ND/HLO | MFU≤ | MFU≤(pallas) |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in sorted(arts, key=_key):
        if a["mesh"] != mesh:
            continue
        if a["status"] == "skipped":
            rows.append(
                f"| {a['arch']} | {a['shape']} | {a['backend']} | — | — "
                f"| — | — | — | skipped (quadratic @500k) | — | — | — |")
            continue
        if a["status"] != "ok":
            continue
        r = a["roofline"]
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['backend']} "
            f"| {_fmt_s(r['t_compute_s'])} | {_fmt_s(r['t_memory_s'])} "
            f"| {_fmt_s(r['t_memory_pallas_s'])} "
            f"| {_fmt_s(r['t_collective_s'])} | {_fmt_s(r['t_bound_s'])} "
            f"| {r['bottleneck']} | {r['model_flops_ratio']:.2f} "
            f"| {r['mfu_bound']*100:.1f}% "
            f"| {r['mfu_bound_pallas']*100:.1f}% |")
    return rows


def dryrun_table(arts: List[Dict]) -> List[str]:
    rows = [
        "| arch | shape | mesh | backend | status | mem/dev | flops/dev "
        "| wire GiB/dev | collectives | compile |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in sorted(arts, key=_key):
        if a["status"] == "skipped":
            rows.append(f"| {a['arch']} | {a['shape']} | {a['mesh']} "
                        f"| {a['backend']} | skipped | — | — | — | — | — |")
            continue
        if a["status"] != "ok":
            rows.append(f"| {a['arch']} | {a['shape']} | {a['mesh']} "
                        f"| {a['backend']} | FAILED | — | — | — | — | — |")
            continue
        mem = a["memory"]["peak_bytes_per_device"] / 2**30
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | {a['backend']} "
            f"| ok | {mem:.2f} GiB | {a['flops_per_device']:.2e} "
            f"| {a['collectives']['wire_bytes']/2**30:.1f} "
            f"| {a['collectives']['count']} | {a['t_compile_s']:.0f}s |")
    return rows


def summary(arts: List[Dict]) -> Dict[str, int]:
    return {
        "ok": sum(a["status"] == "ok" for a in arts),
        "skipped": sum(a["status"] == "skipped" for a in arts),
        "failed": sum(a["status"] == "failed" for a in arts),
    }


def main() -> List[str]:
    arts = load_artifacts()
    s = summary(arts)
    out = [f"roofline,artifacts,{len(arts)},ok,{s['ok']},"
           f"skipped,{s['skipped']},failed,{s['failed']}"]
    for a in sorted(arts, key=_key):
        if a["status"] != "ok":
            continue
        r = a["roofline"]
        out.append(
            f"roofline,{a['arch']},{a['shape']},{a['mesh']},"
            f"{a.get('backend','')},{r['bottleneck']},"
            f"{r['t_bound_s']:.5f},{r['mfu_bound']:.4f}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
