"""The paper's headline scenario: extreme query loads on pre-encoded
documents (§2.2 information retrieval / §6).

Encodes D documents ONCE into fixed-size k×k states, then answers m
queries per document, comparing against softmax attention which must
re-scan all n hidden states per query. Reports throughput
(queries/second) and the store size, for several query loads.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core.linear_attention import encode_document, lookup
from repro.core.softmax_attention import softmax_lookup


def run(n_docs: int = 32, n: int = 750, k: int = 100,
        loads=(1, 16, 256)) -> List[Dict]:
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (n_docs, n, k))
    c = jax.jit(encode_document)(h)
    lin = jax.jit(lookup)
    soft = jax.jit(softmax_lookup)
    rows = []
    for m in loads:
        q = jax.random.normal(jax.random.fold_in(key, m), (n_docs, m, k))
        for fn, name, store in ((lin, "linear", c), (soft, "softmax", h)):
            fn(store, q).block_until_ready()
            t0 = time.perf_counter()
            iters = 20
            for _ in range(iters):
                out = fn(store, q)
            out.block_until_ready()
            dt = (time.perf_counter() - t0) / iters
            rows.append({
                "mechanism": name,
                "queries": n_docs * m,
                "qps": n_docs * m / dt,
                "store_bytes": store.nbytes,
            })
    return rows


def main() -> List[str]:
    out = ["mass_serving,mechanism,total_queries,qps,store_bytes"]
    for r in run():
        out.append(f"mass_serving,{r['mechanism']},{r['queries']},"
                   f"{r['qps']:.0f},{r['store_bytes']}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
