"""The paper's headline scenario: extreme query loads on pre-encoded
documents (§2.2 information retrieval / §6), served by the memory-serving
:class:`repro.serving.LookupEngine`.

Two sweeps, each run for both engine backends:

* **lookups/s vs memory count N** — ingest N documents once (varlen
  batched waves), then drive a query storm that mixes memories inside
  every wave. The linear backend's store is N·k² bytes and every wave is
  ONE ``mass_lookup_indexed`` dispatch regardless of which memories the
  wave touches.
* **lookups/s vs document length n** — same storm, growing documents.
  The linear engine's per-query work and resident bytes are flat in n;
  the softmax baseline rescans (and keeps) all n hidden states per
  query.

Wall-clock rows are informational; the machine-checked **claims** are
deterministic (dispatch counters, FLOPs/memory accounting, bit-identity)
so CI can grep them without timing flakes:

* ``one_dispatch_per_wave`` — every query wave of every run cost exactly
  one jitted lookup dispatch, and waves genuinely mixed memories.
* ``linear_dispatches_independent_of_n`` — the linear engine's dispatch
  count for a fixed storm is identical across document lengths.
* ``linear_flops_constant_in_n`` — per-query FLOPs accounting: linear is
  constant in n while softmax grows.
* ``softmax_resident_grows_with_n`` — resident bytes: linear flat,
  softmax linear-in-n (the fixed-size-representation claim).
* ``engine_state_bitwise_equals_solo`` — every resident memory row is
  bit-identical to the solo ``DocumentState`` (batched admission adds
  zero numerical change to the state).
* ``engine_matches_solo_lookup`` — wave answers match solo
  ``DocumentState.lookup`` to fp32 accumulation-order tolerance.
* ``engine_deterministic_replay`` — replaying the identical storm on a
  fresh engine reproduces every answer bit-for-bit.

Results land in ``BENCH_lookup.json`` at the repo root; ``main()``
prints the CSV rows plus ``lookup_claim,<name>,PASS`` lines CI greps.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.softmax_attention import (lookup_flops_linear,
                                          lookup_flops_softmax)
from repro.core.state import DocumentState
from repro.serving.lookup_engine import LookupEngine

K = 64


def _make_hidden(rng: np.random.Generator, n_docs: int, n: int):
    return [jnp.asarray(rng.standard_normal((n, K)), jnp.float32)
            for _ in range(n_docs)]


def _storm(engine: LookupEngine, rng: np.random.Generator,
           n_queries: int) -> Dict:
    """Drive a mixed-memory query storm; return throughput + counters."""
    doc_ids = list(engine.rows())
    queries = rng.standard_normal((n_queries, K)).astype(np.float32)
    for i in range(n_queries):              # warm the wave programs
        engine.submit(doc_ids[i % len(doc_ids)], queries[i])
    engine.run()
    base = engine.stats.to_dict()
    for i in range(n_queries):
        engine.submit(doc_ids[(i * 7) % len(doc_ids)], queries[i])
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    st = engine.stats
    return {
        "qps": n_queries / max(dt, 1e-9),
        "waves": st.waves - base["waves"],
        "lookup_dispatches": st.lookup_dispatches
        - base["lookup_dispatches"],
        "multi_memory_waves": st.multi_memory_waves
        - base["multi_memory_waves"],
        "jit_misses": st.lookup_jit_misses - base["lookup_jit_misses"],
        "resident_bytes": st.resident_state_bytes,
    }


def sweep_memories(n_docs_grid=(16, 64, 256), n: int = 64,
                   n_queries: int = 512) -> List[Dict]:
    """lookups/s vs resident memory count (fixed doc length)."""
    rows = []
    for backend in ("linear", "softmax"):
        for n_docs in n_docs_grid:
            rng = np.random.default_rng(0)
            eng = LookupEngine(k=K, backend=backend, wave_size=64)
            for i, h in enumerate(_make_hidden(rng, n_docs, n)):
                eng.ingest_hidden(f"doc{i}", h)
            r = _storm(eng, rng, n_queries)
            r.update(backend=backend, n_docs=n_docs, doc_len=n,
                     n_queries=n_queries)
            rows.append(r)
    return rows


def sweep_doc_len(n_grid=(32, 128, 512), n_docs: int = 32,
                  n_queries: int = 512) -> List[Dict]:
    """lookups/s vs document length (fixed memory count)."""
    rows = []
    for backend in ("linear", "softmax"):
        for n in n_grid:
            rng = np.random.default_rng(1)
            eng = LookupEngine(k=K, backend=backend, wave_size=64)
            for i, h in enumerate(_make_hidden(rng, n_docs, n)):
                eng.ingest_hidden(f"doc{i}", h)
            r = _storm(eng, rng, n_queries)
            r.update(backend=backend, n_docs=n_docs, doc_len=n,
                     n_queries=n_queries)
            rows.append(r)
    return rows


def check_parity(n_docs: int = 8, n: int = 96,
                 n_queries: int = 64) -> Dict[str, bool]:
    """Three engine-vs-solo invariants.

    * The resident state ROW is bitwise-equal to the solo
      ``DocumentState`` — batching the admission adds zero numerical
      change to the memory itself.
    * Wave answers match solo ``lookup`` to fp32 accumulation-order
      tolerance (a batched GEMM need not share the solo GEMM's
      reduction order bit-for-bit).
    * Replaying the identical storm on a fresh engine reproduces every
      answer bit-for-bit — bucketing/padding/wave composition is
      deterministic.
    """
    def run_storm():
        rng = np.random.default_rng(2)
        hs = _make_hidden(rng, n_docs, n)
        eng = LookupEngine(k=K, backend="linear", wave_size=16)
        for i, h in enumerate(hs):
            eng.ingest_hidden(f"doc{i}", h)
        submitted = {}
        for i in range(n_queries):
            q = rng.standard_normal((1 + i % 3, K)).astype(np.float32)
            submitted[eng.submit(f"doc{i % n_docs}", q)] = (i % n_docs, q)
        return eng, hs, submitted, eng.run()

    eng, hs, submitted, results = run_storm()
    states = [DocumentState.from_hidden_states(h) for h in hs]
    state_bitwise = all(
        np.array_equal(np.asarray(eng.store["c"][eng.rows()[f"doc{i}"]]),
                       np.asarray(states[i].c))
        for i in range(n_docs))
    solo_close = all(
        np.allclose(np.asarray(states[doc].lookup(jnp.asarray(q))),
                    r.answers, rtol=1e-4, atol=1e-4)
        for r in results for doc, q in [submitted[r.uid]])
    _, _, _, replay = run_storm()
    replay_bitwise = all(
        np.array_equal(a.answers, b.answers)
        for a, b in zip(results, replay))
    return {"engine_state_bitwise_equals_solo": state_bitwise,
            "engine_matches_solo_lookup": solo_close,
            "engine_deterministic_replay": replay_bitwise}


def evaluate_claims(mem_rows: List[Dict], len_rows: List[Dict]) -> Dict:
    every = mem_rows + len_rows
    lin = [r for r in len_rows if r["backend"] == "linear"]
    soft = [r for r in len_rows if r["backend"] == "softmax"]
    lin_flops = [lookup_flops_linear(K) for _ in lin]
    soft_flops = [lookup_flops_softmax(r["doc_len"], K) for r in soft]
    return {
        "one_dispatch_per_wave": all(
            r["lookup_dispatches"] == r["waves"]
            and r["multi_memory_waves"] > 0 for r in every),
        "linear_dispatches_independent_of_n": len(
            {r["lookup_dispatches"] for r in lin}) == 1,
        "linear_flops_constant_in_n": (
            len(set(lin_flops)) == 1
            and soft_flops == sorted(soft_flops)
            and soft_flops[-1] > lin_flops[0]),
        "softmax_resident_grows_with_n": (
            len({r["resident_bytes"] for r in lin}) == 1
            and [r["resident_bytes"] for r in soft]
            == sorted({r["resident_bytes"] for r in soft})
            and soft[-1]["resident_bytes"] > lin[-1]["resident_bytes"]),
        **check_parity(),
    }


def main() -> List[str]:
    mem_rows = sweep_memories()
    len_rows = sweep_doc_len()
    claims = evaluate_claims(mem_rows, len_rows)

    payload = {
        "k": K,
        "lookups_per_s_vs_memory_count": mem_rows,
        "lookups_per_s_vs_doc_len": len_rows,
        "claims": claims,
    }
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir, "BENCH_lookup.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)

    out = ["mass_serving,sweep,backend,n_docs,doc_len,qps,waves,"
           "dispatches,resident_bytes"]
    for sweep, rows in (("memories", mem_rows), ("doc_len", len_rows)):
        for r in rows:
            out.append(
                f"mass_serving,{sweep},{r['backend']},{r['n_docs']},"
                f"{r['doc_len']},{r['qps']:.0f},{r['waves']},"
                f"{r['lookup_dispatches']},{r['resident_bytes']}")
    for name, ok in claims.items():
        out.append(f"lookup_claim,{name},{'PASS' if ok else 'FAIL'}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
