"""Continuous vs. static batching, and batched vs. per-request admission.

Part 1 — scheduling (PR 2): the paper's fixed-size O(k²) states make
slot admission a cheap copy, so the serving engine can refill freed
slots *between scan segments* instead of waiting for the whole batch to
drain. Measured on a skewed generation-length mix (most requests short,
every 4th a long straggler), the shape under which batch-synchronous
("static") serving idles most of its slots behind the straggler.
Both policies run through the SAME engine instance and compiled
programs, so the comparison isolates scheduling; claimed ≥ 1.5×
continuous over static for the linear backend.

Part 2 — admission (PR 4): the per-request prefill-on-admit path pays
one host-blocking batch-1 ``lm.prefill`` per request — and one jit
compile per DISTINCT prompt length — then a slot write, stalling the
fused decode loop at every admission. Batched admission bucket-pads the
whole admission wave to a power-of-2 width and encodes it with ONE
``lm.prefill_varlen`` dispatch (per-row masking keeps every row
bit-identical to prefilling alone); prompts longer than
``prefill_chunk`` continue through ``lm.decode_window_varlen`` chunks
INTERLEAVED with decode segments. Measured on the long-prompt skewed
mix (every 4th prompt 8× longer, prompt lengths varied so the
per-request path actually recompiles): claimed ≥ 1.3× aggregate
tokens/s with bit-identical greedy outputs on linear, gated_linear and
softmax, plus deterministic dispatch-count / jit-miss / interleave
claims for CI.

Part 3 — heterogeneous fleet (PR 7): the :class:`DecodeBackend` seam
makes the engine a pure scheduler, so ONE admission queue can serve
slot groups holding *different architecture families* — linear
(fixed-state attention), softmax (growing KV cache) and mamba2 (SSD
state) side by side, each group with its own compiled segment
programs. Claims are deterministic: greedy outputs bit-identical to
three homogeneous engines fed the same per-group submissions, exactly
one compiled decode-segment program per backend (== the number of
distinct backends in the fleet), and the fleet genuinely mixes state
layouts (fixed-size and growing in the same queue).

Part 4 — prefix caching (PR 10): shared prompt prefixes are admitted
from a content-hash cache — the fixed-size families pay ONE O(k²)
state copy + suffix-only prefill per hit (flat bytes per cached
prefix), the softmax baseline reuses refcounted paged KV blocks (bytes
∝ prefix tokens). Claims: off/cold/warm outputs bit-identical on
linear, gated_linear and softmax; a fully-warm run re-encodes zero
prompts; cold admission ≥ 1.3× the warm run's admission dispatches;
linear cached bytes FLAT vs softmax growing in prefix length; fork=N
equals N independent submits with one prompt encode.

Results land in ``BENCH_serving.json`` at the repo root so the serving
trajectory is tracked across PRs (CPU smoke config: RATIOS are the
validated claims, not absolute tokens/s).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import make_request_mix
from repro.models import lm
from repro.serving import DecodeEngine
from repro.sharding import Rules

RULES = Rules.null()
N_SLOTS = 4
SEGMENT_LEN = 8
PROMPT_LEN = 8
GEN_LONG = 64           # every 4th request (one straggler per static batch)
GEN_SHORT = max(1, GEN_LONG // 8)   # the ratio make_request_mix generates
N_REQUESTS = 16
REPEATS = 2             # best-of, interleaved across policies
BENCH_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_serving.json")


def _workload(vocab_size: int):
    """The serve.py --mode stream straggler mix (every 4th request
    ``GEN_LONG`` = 8× ``GEN_SHORT``), all arriving at t=0 — ONE shared
    generator so the CI smoke and this claim exercise the same shape."""
    rng = np.random.default_rng(0)
    return make_request_mix(rng, N_REQUESTS, PROMPT_LEN, GEN_LONG,
                            vocab_size, arrival_rate=0.0)


def _run_policy(engine: DecodeEngine, workload, policy: str):
    """One full pass: reset, submit everything at t=0, drain."""
    engine.reset()
    for prompt, g, _ in workload:
        engine.submit(prompt, g)
    t0 = time.perf_counter()
    completions = engine.run(policy)
    dt = time.perf_counter() - t0
    tokens = sum(len(c.tokens) for c in completions)
    return (dt, tokens, engine.stats.slot_utilization,
            engine.stats.segments, completions)


def run(backends=("linear", "softmax")) -> List[Dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    for backend in backends:
        # fp32 on CPU (XLA emulates bf16 with converts around every op);
        # kernel selection stays "auto" — the engine path as deployed
        cfg = dataclasses.replace(
            get_smoke_config("yi-34b").with_backend(backend),
            dtype="float32")
        params = lm.init_params(key, cfg)
        engine = DecodeEngine(
            params, cfg, RULES, n_slots=N_SLOTS, segment_len=SEGMENT_LEN,
            max_len=PROMPT_LEN + GEN_LONG + SEGMENT_LEN)
        workload = _workload(cfg.vocab_size)

        _run_policy(engine, workload, "continuous")     # compile
        best = {"static": None, "continuous": None}
        for _ in range(REPEATS):
            for policy in ("static", "continuous"):
                r = _run_policy(engine, workload, policy)
                if best[policy] is None or r[0] < best[policy][0]:
                    best[policy] = r
        (t_s, tok_s, util_s, seg_s, comps_s) = best["static"]
        (t_c, tok_c, util_c, seg_c, comps_c) = best["continuous"]
        # the engine's bit-identity contract, enforced in the exact
        # binary CI runs: scheduling must not change a single token
        for a, b in zip(comps_s, comps_c):
            assert a.uid == b.uid and np.array_equal(a.tokens, b.tokens), \
                f"policies diverged on request {a.uid}"
        rows.append({
            "backend": backend,
            "n_slots": N_SLOTS, "segment_len": SEGMENT_LEN,
            "n_requests": N_REQUESTS, "total_tokens": tok_c,
            "static_tokens_per_s": tok_s / t_s,
            "continuous_tokens_per_s": tok_c / t_c,
            "static_slot_utilization": util_s,
            "continuous_slot_utilization": util_c,
            "static_segments": seg_s,
            "continuous_segments": seg_c,
            "continuous_speedup": t_s / t_c,
        })
    return rows


# ---------------------------------------------------------------------------
# Part 2 — batched + chunked admission vs per-request prefill-on-admit
# ---------------------------------------------------------------------------

ADM_N_REQUESTS = 16
ADM_PROMPT_BASES = list(range(5, 17))   # varied lengths → jit churn
ADM_LONG_FACTOR = 8                 # every 4th prompt 8× longer
ADM_GEN_LEN = 12
ADM_PREFILL_CHUNK = 16              # long prompts take 3-6 chunks


def _admission_workload(vocab_size: int):
    """Long-prompt skewed mix: every 4th prompt 8× longer, lengths
    varied within the mix (12 distinct lengths across 16 requests —
    the shape of real traffic) so per-request admission compiles a new
    prefill program per length while batched admission reuses its
    power-of-2 bucket programs."""
    rng = np.random.default_rng(1)
    out = []
    for i in range(ADM_N_REQUESTS):
        base = ADM_PROMPT_BASES[i % len(ADM_PROMPT_BASES)]
        p_len = base * ADM_LONG_FACTOR if i % 4 == 0 else base
        prompt = rng.integers(0, vocab_size, size=p_len,
                              dtype=np.int64).astype(np.int32)
        out.append((prompt, ADM_GEN_LEN))
    return out


def _run_admission(engine: DecodeEngine, workload):
    engine.reset()
    for prompt, g in workload:
        engine.submit(prompt, g)
    t0 = time.perf_counter()
    completions = engine.run("continuous")
    dt = time.perf_counter() - t0
    return completions, dt


def run_admission() -> Dict:
    """Batched+chunked vs per-request admission.

    The HEADLINE number is first-service (cold) aggregate tokens/s on a
    fresh engine: per-request admission host-blocks on one batch-1
    ``lm.prefill`` compile per DISTINCT prompt length (12 in this mix —
    and real traffic never stops producing new lengths), while batched
    admission compiles one program per power-of-2 bucket width, a
    fixed O(log prefill_chunk) set. Steady-state (warm, best-of) is
    reported alongside: on this compute-bound CPU smoke the bucket
    padding costs real FLOPs, so the warm ratio underestimates what a
    dispatch-bound accelerator sees; the deterministic dispatch/miss
    counts are the device-independent form. Bit-identity of greedy
    outputs vs the per-request path is asserted on all three backends.
    """
    key = jax.random.PRNGKey(0)
    max_prompt = max(ADM_PROMPT_BASES) * ADM_LONG_FACTOR
    max_len = max_prompt + ADM_GEN_LEN + SEGMENT_LEN

    def make_engine(cfg, params, admission):
        return DecodeEngine(
            params, cfg, RULES, n_slots=N_SLOTS,
            segment_len=SEGMENT_LEN, max_len=max_len,
            admission=admission, prefill_chunk=ADM_PREFILL_CHUNK)

    rows = []
    identical = True
    for backend in ("linear", "gated_linear", "softmax"):
        # fp32: argmax margins far above the chunked-ingest vs one-shot
        # prefill reassociation noise (the same precedent as spec mode)
        cfg = dataclasses.replace(
            get_smoke_config("yi-34b").with_backend(backend),
            dtype="float32")
        params = lm.init_params(key, cfg)
        workload = _admission_workload(cfg.vocab_size)
        engines = {adm: make_engine(cfg, params, adm)
                   for adm in ("per_request", "batched")}

        cold_t: Dict[str, float] = {}
        stats: Dict[str, Dict] = {}
        comps: Dict[str, list] = {}
        for adm, eng in engines.items():
            # first service on a fresh engine: admission compiles land
            # here, exactly as they would on a serving process meeting
            # this traffic for the first time
            comps[adm], cold_t[adm] = _run_admission(eng, workload)
            st = eng.stats
            stats[adm] = {
                "jit_misses": st.prefill_jit_misses,
                "admission_dispatches": st.admission_dispatches,
                "admission_batches": st.admission_batches,
                "mean_admission_batch": st.mean_admission_batch,
                "ingest_chunks": st.ingest_chunks,
                "interleave_ratio": st.interleave_ratio,
                "segments": st.segments,
            }
        # the bit-identity bar: batched+chunked admission must not
        # change a single greedy token vs the PR-3 per-request path
        for a, b in zip(comps["per_request"], comps["batched"]):
            if not (a.uid == b.uid and np.array_equal(a.tokens,
                                                      b.tokens)):
                identical = False

        warm = {adm: float("inf") for adm in engines}
        if backend == "linear":                 # wall clock: linear only
            for _ in range(REPEATS):
                for adm, eng in engines.items():
                    _, dt = _run_admission(eng, workload)
                    warm[adm] = min(warm[adm], dt)
        total = sum(len(c.tokens) for c in comps["batched"])
        lin_only = backend == "linear"
        rows.append({
            "backend": backend,
            "total_tokens": total,
            "per_request": stats["per_request"],
            "batched": stats["batched"],
            "cold_per_request_tokens_per_s":
                total / cold_t["per_request"] if lin_only else None,
            "cold_batched_tokens_per_s":
                total / cold_t["batched"] if lin_only else None,
            "admission_speedup":
                (cold_t["per_request"] / cold_t["batched"]
                 if lin_only else None),
            "warm_per_request_tokens_per_s":
                total / warm["per_request"] if lin_only else None,
            "warm_batched_tokens_per_s":
                total / warm["batched"] if lin_only else None,
            "warm_admission_speedup":
                (warm["per_request"] / warm["batched"]
                 if lin_only else None),
        })

    lin = next(r for r in rows if r["backend"] == "linear")
    claims = {
        # the acceptance bar: ≥1.3× first-service aggregate tokens/s on
        # the long-prompt skewed mix (the recompile-bound regime the
        # bucketing exists for)
        "admission_1p3x_over_per_request":
            lin["admission_speedup"] >= 1.3,
        # deterministic forms for CI (wall clock flakes under load):
        # the batched path issues ≥1.3× fewer admission device calls...
        "admission_fewer_dispatches": all(
            r["per_request"]["admission_dispatches"]
            >= 1.3 * r["batched"]["admission_dispatches"] for r in rows),
        # ...compiles ≥2× fewer admission programs (a FIXED set of
        # power-of-2 bucket programs vs one compile per distinct prompt
        # length — the per-request count keeps growing with traffic
        # diversity, the bucket count cannot exceed O(log prefill_chunk))
        "admission_2x_fewer_jit_misses": all(
            r["per_request"]["jit_misses"]
            >= 2 * r["batched"]["jit_misses"] for r in rows),
        # ...and long-prompt chunked ingest ran with decode slots live
        "chunked_prefill_interleaves_decode": all(
            r["batched"]["ingest_chunks"] > 0
            and r["batched"]["interleave_ratio"] > 0 for r in rows),
        "admission_outputs_bit_identical": identical,
    }
    return {
        "n_slots": N_SLOTS, "segment_len": SEGMENT_LEN,
        "prefill_chunk": ADM_PREFILL_CHUNK,
        "workload": {"n_requests": ADM_N_REQUESTS,
                     "prompt_bases": ADM_PROMPT_BASES,
                     "long_factor": ADM_LONG_FACTOR,
                     "gen_len": ADM_GEN_LEN},
        "rows": rows, "claims": claims,
    }


# ---------------------------------------------------------------------------
# Part 3 — heterogeneous backend fleet: one queue, three families
# ---------------------------------------------------------------------------

FLEET_BACKENDS = ("linear", "softmax", "mamba2")
FLEET_N_REQUESTS = 12
FLEET_N_SLOTS = 2               # per group
FLEET_GEN_LEN = 24


def run_fleet() -> Dict:
    """Mixed-fleet serving: the straggler mix round-robined across
    three backend slot groups behind one admission queue. Wall-clock
    per-group tokens/s is reported for the trajectory; the VALIDATED
    claims are the deterministic ones (bit-identity vs homogeneous
    runs, one compiled segment program per backend)."""
    from repro.serving import FleetEngine, fleet_demo_config

    key = jax.random.PRNGKey(0)
    groups = {}
    for i, name in enumerate(FLEET_BACKENDS):
        cfg = fleet_demo_config(name)
        groups[name] = (lm.init_params(jax.random.fold_in(key, i), cfg),
                        cfg)
    vocab = min(cfg.vocab_size for _, cfg in groups.values())
    rng = np.random.default_rng(2)
    workload = make_request_mix(rng, FLEET_N_REQUESTS, PROMPT_LEN,
                                FLEET_GEN_LEN, vocab, arrival_rate=0.0)
    route = [FLEET_BACKENDS[i % len(FLEET_BACKENDS)]
             for i in range(FLEET_N_REQUESTS)]
    max_len = PROMPT_LEN + FLEET_GEN_LEN + SEGMENT_LEN

    fleet = FleetEngine(groups, n_slots=FLEET_N_SLOTS,
                        segment_len=SEGMENT_LEN, max_len=max_len)

    def run_once():
        fleet.reset()
        for (prompt, g, _), name in zip(workload, route):
            fleet.submit(prompt, g, backend=name)
        t0 = time.perf_counter()
        comps = fleet.run("continuous")
        return comps, time.perf_counter() - t0

    comps, _ = run_once()                           # compile
    best = float("inf")
    deterministic = True
    for _ in range(REPEATS):
        comps2, dt = run_once()
        best = min(best, dt)
        deterministic &= all(
            np.array_equal(a.tokens, b.tokens)
            for a, b in zip(comps, comps2))

    # bit-identity vs three homogeneous engines, same per-group feeds
    identical = deterministic
    for name in FLEET_BACKENDS:
        params, cfg = groups[name]
        eng = DecodeEngine(params, cfg, RULES, n_slots=FLEET_N_SLOTS,
                           segment_len=SEGMENT_LEN, max_len=max_len)
        for (prompt, g, _), rname in zip(workload, route):
            if rname == name:
                eng.submit(prompt, g)
        solo = eng.run("continuous")
        mine = [c for c, rname in zip(comps, route) if rname == name]
        for a, b in zip(mine, solo):
            if not np.array_equal(a.tokens, b.tokens):
                identical = False

    programs = fleet.compiled_segment_programs()
    stats = fleet.stats()
    rows = []
    for name in FLEET_BACKENDS:
        g = stats["groups"][name]
        toks = sum(len(c.tokens)
                   for c, rname in zip(comps, route) if rname == name)
        rows.append({
            "group": name,
            "backend": g["backend"],
            "fixed_size_state": g["fixed_size_state"],
            "state_bytes_per_slot": g["state_bytes_per_slot"],
            "tokens": toks,
            "tokens_per_s": toks / best,
            "compiled_segment_programs": g["compiled_segment_programs"],
            "slot_utilization": g["stats"]["slot_utilization"],
        })
    total = sum(r["tokens"] for r in rows)
    claims = {
        "fleet_outputs_bit_identical": identical,
        # exactly one decode-segment program per backend: the compiled-
        # program count equals the number of distinct backends served
        "fleet_one_program_per_backend": (
            len(programs) == len(set(FLEET_BACKENDS))
            and all(v == 1 for v in programs.values())),
        # the queue genuinely mixes state layouts: fixed-size O(k²)
        # families and the growing KV cache served side by side
        "fleet_mixes_state_layouts": (
            any(r["fixed_size_state"] for r in rows)
            and any(not r["fixed_size_state"] for r in rows)),
    }
    return {
        "backends": list(FLEET_BACKENDS),
        "n_slots_per_group": FLEET_N_SLOTS,
        "segment_len": SEGMENT_LEN,
        "workload": {"n_requests": FLEET_N_REQUESTS,
                     "prompt_len": PROMPT_LEN,
                     "gen_len": FLEET_GEN_LEN},
        "aggregate_tokens_per_s": total / best,
        "rows": rows, "claims": claims,
    }


# ---------------------------------------------------------------------------
# Part 4 — prefix caching: O(k²) hit admission vs paged softmax KV
# ---------------------------------------------------------------------------

CACHE_BACKENDS = ("linear", "gated_linear", "softmax")
CACHE_PREFIX = 96               # shared system-prompt prefix (3 chunks)
CACHE_TAIL = 8                  # unique per-request suffix
CACHE_N_REQUESTS = 8
CACHE_GEN_LEN = 12
CACHE_CHUNK = 32
CACHE_FORK = 3


def _cache_workload(vocab_size: int):
    """Shared-prefix traffic: every prompt starts with the same
    ``CACHE_PREFIX`` tokens (the system-prompt / few-shot-header shape
    prefix caching exists for) and diverges in its last ``CACHE_TAIL``."""
    rng = np.random.default_rng(4)
    shared = rng.integers(0, vocab_size, size=CACHE_PREFIX,
                          dtype=np.int64).astype(np.int32)
    return [np.concatenate(
        [shared, rng.integers(0, vocab_size, size=CACHE_TAIL,
                              dtype=np.int64).astype(np.int32)])
        for _ in range(CACHE_N_REQUESTS)]


def run_prefix_cache() -> Dict:
    """Cache-off vs cold vs warm admission on shared-prefix traffic.

    The VALIDATED claims are deterministic (CI-gated): outputs
    bit-identical across off/cold/warm on every backend, a fully-warm
    run re-encodes ZERO prompts (``prefills == 0`` — each admission is
    one state copy + suffix-only ingest), cold admission encodes
    ≥ 1.3× the warm run's prompt tokens (warm runs only the
    post-boundary suffixes through prefill/ingest programs — the
    cached prefix is one flat state copy), the linear family's cached
    bytes are FLAT in prefix length while the softmax blocks grow ∝
    tokens, and ``fork=N`` equals N independent submits with ONE
    prompt encode. Wall-clock first-service speedup is reported for
    the trajectory."""
    key = jax.random.PRNGKey(0)
    rows = []
    fork_claims = []
    for backend in CACHE_BACKENDS:
        cfg = dataclasses.replace(
            get_smoke_config("yi-34b").with_backend(backend),
            dtype="float32")
        params = lm.init_params(key, cfg)
        prompts = _cache_workload(cfg.vocab_size)
        prompt_tokens = sum(len(p) for p in prompts)
        max_len = (CACHE_PREFIX + CACHE_TAIL + CACHE_GEN_LEN
                   + SEGMENT_LEN)
        kw = dict(n_slots=N_SLOTS, segment_len=SEGMENT_LEN,
                  max_len=max_len, prefill_chunk=CACHE_CHUNK)
        off = DecodeEngine(params, cfg, RULES, **kw)
        eng = DecodeEngine(params, cfg, RULES, prefix_cache="auto", **kw)

        def run_once(engine, fork=1):
            engine.reset()
            for p in prompts:
                engine.submit(p, CACHE_GEN_LEN, fork=fork)
            t0 = time.perf_counter()
            comps = engine.run("continuous")
            return comps, time.perf_counter() - t0

        comps_off, _ = run_once(off)
        run_once(eng)               # compile cold programs + fill cache
        run_once(eng)               # compile the hit-admission programs
        eng.cache.clear()
        comps_cold, t_cold = run_once(eng)
        cold = {"prefills": eng.stats.prefills,
                "admission_dispatches": eng.stats.admission_dispatches,
                "ingest_chunks": eng.stats.ingest_chunks,
                "cache_hits": eng.stats.cache_hits,
                "cache_misses": eng.stats.cache_misses,
                "cached_prefix_tokens": eng.stats.cached_prefix_tokens}
        comps_warm, t_warm = run_once(eng)
        warm = {"prefills": eng.stats.prefills,
                "admission_dispatches": eng.stats.admission_dispatches,
                "ingest_chunks": eng.stats.ingest_chunks,
                "cache_hits": eng.stats.cache_hits,
                "cached_prefix_tokens": eng.stats.cached_prefix_tokens}

        identical = all(
            np.array_equal(a.tokens, b.tokens)
            and np.array_equal(a.tokens, c.tokens)
            for a, b, c in zip(comps_off, comps_cold, comps_warm))
        # the byte-cost claim, measured on the resident cache: the
        # 32-token and 96-token prefixes of the SAME prompt
        b32 = eng.cache.prefix_nbytes(prompts[0], CACHE_CHUNK)
        b96 = eng.cache.prefix_nbytes(prompts[0], CACHE_PREFIX)

        # fork/n-best vs N independent submits (cache off: the claim
        # is about the shared prefill snapshot, not the cache)
        off.reset()
        for _ in range(CACHE_FORK):
            off.submit(prompts[0], CACHE_GEN_LEN)
        indep = off.run("continuous")
        off.reset()
        off.submit(prompts[0], CACHE_GEN_LEN, fork=CACHE_FORK)
        forked = off.run("continuous")
        fork_ok = (len(forked) == CACHE_FORK
                   and all(np.array_equal(a.tokens, b.tokens)
                           for a, b in zip(indep, forked))
                   and off.stats.prefills == 1
                   and off.stats.forks == CACHE_FORK - 1)
        fork_claims.append(fork_ok)

        rows.append({
            "backend": backend,
            "cache_kind": eng.cache.name,
            "fixed_size_state": eng.backend.fixed_size_state,
            "outputs_bit_identical": identical,
            "cold": cold, "warm": warm,
            "cold_tokens_per_s":
                sum(len(c.tokens) for c in comps_cold) / t_cold,
            "warm_tokens_per_s":
                sum(len(c.tokens) for c in comps_warm) / t_warm,
            "warm_admission_speedup": t_cold / t_warm,
            # admission ENCODE work, in tokens: every prompt token not
            # served from the cache runs through a prefill/ingest
            # program. The hit path replaces that with one O(k²) flat
            # state copy, so the deterministic form of the ≥1.3×
            # first-service claim is the encoded-token ratio — dispatch
            # COUNTS alone can't show it (cold batches 4 prompts into
            # one prefill wave; warm pays one copy dispatch per hit).
            "cold_encoded_tokens":
                prompt_tokens - cold["cached_prefix_tokens"],
            "warm_encoded_tokens":
                prompt_tokens - warm["cached_prefix_tokens"],
            "encode_work_ratio": (
                (prompt_tokens - cold["cached_prefix_tokens"])
                / max(prompt_tokens - warm["cached_prefix_tokens"], 1)),
            "prefix_nbytes_32": b32,
            "prefix_nbytes_96": b96,
            "cache_bytes_used": eng.cache.bytes_used,
            "fork_bit_identical_one_prefill": fork_ok,
        })

    lin = [r for r in rows if r["fixed_size_state"]]
    sm = next(r for r in rows if r["backend"] == "softmax")
    claims = {
        "cache_outputs_bit_identical": all(
            r["outputs_bit_identical"] for r in rows),
        # deterministic hit-admission form: a fully-warm run re-encodes
        # ZERO prompts and serves every admission from the cache
        "cache_warm_zero_prefills": all(
            r["warm"]["prefills"] == 0
            and r["warm"]["cache_hits"] == CACHE_N_REQUESTS
            and r["warm"]["cached_prefix_tokens"]
            == CACHE_PREFIX * CACHE_N_REQUESTS for r in rows),
        # the ≥1.3× first-service claim in deterministic work-count
        # form (cannot flake under host load): cold admission encodes
        # ≥1.3× the warm run's prompt tokens on every backend (warm
        # serves the shared prefix as one flat O(k²) state copy)
        "cache_hit_1p3x_less_encode_work": all(
            r["encode_work_ratio"] >= 1.3 for r in rows),
        # the paper's cost claim in bytes: tripling the cached prefix
        # leaves a fixed-size entry FLAT while softmax blocks triple
        "linear_cache_bytes_flat": all(
            r["prefix_nbytes_96"] == r["prefix_nbytes_32"] > 0
            for r in lin),
        "softmax_cache_bytes_grow":
            sm["prefix_nbytes_96"] == 3 * sm["prefix_nbytes_32"] > 0,
        "fork_bit_identical_one_prefill": all(fork_claims),
    }
    return {
        "backends": list(CACHE_BACKENDS),
        "workload": {"n_requests": CACHE_N_REQUESTS,
                     "shared_prefix": CACHE_PREFIX,
                     "tail": CACHE_TAIL, "gen_len": CACHE_GEN_LEN,
                     "chunk": CACHE_CHUNK, "fork": CACHE_FORK},
        "rows": rows, "claims": claims,
    }


def main() -> List[str]:
    rows = run()
    out = ["continuous_batching,backend,static_tok_s,continuous_tok_s,"
           "static_util,continuous_util,speedup"]
    for r in rows:
        out.append(
            f"continuous_batching,{r['backend']},"
            f"{r['static_tokens_per_s']:.0f},"
            f"{r['continuous_tokens_per_s']:.0f},"
            f"{r['static_slot_utilization']:.2f},"
            f"{r['continuous_slot_utilization']:.2f},"
            f"{r['continuous_speedup']:.2f}")
    lin = next(r for r in rows if r["backend"] == "linear")
    claims = {
        # the acceptance bar: refilling freed slots beats batch-sync by
        # ≥1.5× aggregate tokens/s on the skewed mix
        "continuous_1p5x_over_static": lin["continuous_speedup"] >= 1.5,
        # deterministic form of the same claim for CI gating: segment
        # count is pure scheduling (device cost per segment is equal
        # across policies), so the ratio cannot flake under host load
        "continuous_1p5x_fewer_segments":
            lin["static_segments"] >= 1.5 * lin["continuous_segments"],
        "utilization_improves": all(
            r["continuous_slot_utilization"]
            > r["static_slot_utilization"] for r in rows),
    }
    for name, ok in claims.items():
        out.append(f"continuous_batching_claim,{name},"
                   f"{'PASS' if ok else 'FAIL'}")

    adm = run_admission()
    out.append("admission,backend,cold_pr_tok_s,cold_batched_tok_s,"
               "cold_speedup,warm_speedup,pr_dispatches,"
               "batched_dispatches,pr_misses,batched_misses,chunks,"
               "interleave")
    for r in adm["rows"]:
        spd = r["admission_speedup"]
        wspd = r["warm_admission_speedup"]
        out.append(
            f"admission,{r['backend']},"
            f"{(r['cold_per_request_tokens_per_s'] or 0):.0f},"
            f"{(r['cold_batched_tokens_per_s'] or 0):.0f},"
            f"{(spd if spd is not None else 0):.2f},"
            f"{(wspd if wspd is not None else 0):.2f},"
            f"{r['per_request']['admission_dispatches']},"
            f"{r['batched']['admission_dispatches']},"
            f"{r['per_request']['jit_misses']},"
            f"{r['batched']['jit_misses']},"
            f"{r['batched']['ingest_chunks']},"
            f"{r['batched']['interleave_ratio']:.2f}")
    for name, ok in adm["claims"].items():
        out.append(f"admission_claim,{name},{'PASS' if ok else 'FAIL'}")

    flt = run_fleet()
    out.append("fleet,group,backend,fixed_state,state_bytes_per_slot,"
               "tokens,tok_s,segment_programs,slot_util")
    for r in flt["rows"]:
        out.append(
            f"fleet,{r['group']},{r['backend']},"
            f"{r['fixed_size_state']},{r['state_bytes_per_slot']},"
            f"{r['tokens']},{r['tokens_per_s']:.0f},"
            f"{r['compiled_segment_programs']},"
            f"{r['slot_utilization']:.2f}")
    for name, ok in flt["claims"].items():
        out.append(f"fleet_claim,{name},{'PASS' if ok else 'FAIL'}")

    pc = run_prefix_cache()
    out.append("cache,backend,kind,cold_tok_s,warm_tok_s,warm_speedup,"
               "encode_work_ratio,cold_encoded_tokens,"
               "warm_encoded_tokens,warm_prefills,bytes_32,bytes_96")
    for r in pc["rows"]:
        out.append(
            f"cache,{r['backend']},{r['cache_kind']},"
            f"{r['cold_tokens_per_s']:.0f},{r['warm_tokens_per_s']:.0f},"
            f"{r['warm_admission_speedup']:.2f},"
            f"{r['encode_work_ratio']:.2f},"
            f"{r['cold_encoded_tokens']},"
            f"{r['warm_encoded_tokens']},"
            f"{r['warm']['prefills']},"
            f"{r['prefix_nbytes_32']},{r['prefix_nbytes_96']}")
    for name, ok in pc["claims"].items():
        out.append(f"cache_claim,{name},{'PASS' if ok else 'FAIL'}")

    with open(BENCH_PATH, "w") as f:
        json.dump({"n_slots": N_SLOTS, "segment_len": SEGMENT_LEN,
                   "workload": {"n_requests": N_REQUESTS,
                                "prompt_len": PROMPT_LEN,
                                "gen_long": GEN_LONG,
                                "gen_short": GEN_SHORT},
                   "rows": rows, "claims": claims,
                   "admission": adm, "fleet": flt,
                   "prefix_cache": pc}, f, indent=2)
    return out


if __name__ == "__main__":
    print("\n".join(main()))
