"""Continuous vs. static batching on a skewed request-length mix.

The paper's fixed-size O(k²) states make slot admission a cheap copy, so
the serving engine can refill freed slots *between scan segments*
instead of waiting for the whole batch to drain. This benchmark measures
what that scheduling freedom is worth on the workload it exists for —
a skewed generation-length mix (most requests short, every 4th a long
straggler), the shape under which batch-synchronous ("static") serving
idles most of its slots behind the straggler.

Both policies run through the SAME engine instance and the same
compiled segment/prefill programs (``DecodeEngine.run(policy=...)``), so
the comparison isolates scheduling: identical per-segment device cost,
identical prefill count, identical per-request outputs (the engine's
bit-identity contract). Reported per backend (linear = fixed-state
admission, softmax = KV-cache baseline):

* aggregate tokens/s over the full workload (wall clock, post-compile),
* slot utilization (fraction of scanned slot-steps emitting a token),
* continuous/static speedup — claimed ≥ 1.5× for the linear backend.

Results land in ``BENCH_serving.json`` at the repo root so the serving
trajectory is tracked across PRs (CPU smoke config: RATIOS are the
validated claims, not absolute tokens/s).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import make_request_mix
from repro.models import lm
from repro.serving import DecodeEngine
from repro.sharding import Rules

RULES = Rules.null()
N_SLOTS = 4
SEGMENT_LEN = 8
PROMPT_LEN = 8
GEN_LONG = 64           # every 4th request (one straggler per static batch)
GEN_SHORT = max(1, GEN_LONG // 8)   # the ratio make_request_mix generates
N_REQUESTS = 16
REPEATS = 2             # best-of, interleaved across policies
BENCH_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_serving.json")


def _workload(vocab_size: int):
    """The serve.py --mode stream straggler mix (every 4th request
    ``GEN_LONG`` = 8× ``GEN_SHORT``), all arriving at t=0 — ONE shared
    generator so the CI smoke and this claim exercise the same shape."""
    rng = np.random.default_rng(0)
    return make_request_mix(rng, N_REQUESTS, PROMPT_LEN, GEN_LONG,
                            vocab_size, arrival_rate=0.0)


def _run_policy(engine: DecodeEngine, workload, policy: str):
    """One full pass: reset, submit everything at t=0, drain."""
    engine.reset()
    for prompt, g, _ in workload:
        engine.submit(prompt, g)
    t0 = time.perf_counter()
    completions = engine.run(policy)
    dt = time.perf_counter() - t0
    tokens = sum(len(c.tokens) for c in completions)
    return (dt, tokens, engine.stats.slot_utilization,
            engine.stats.segments, completions)


def run(backends=("linear", "softmax")) -> List[Dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    for backend in backends:
        # fp32 on CPU (XLA emulates bf16 with converts around every op);
        # kernel selection stays "auto" — the engine path as deployed
        cfg = dataclasses.replace(
            get_smoke_config("yi-34b").with_backend(backend),
            dtype="float32")
        params = lm.init_params(key, cfg)
        engine = DecodeEngine(
            params, cfg, RULES, n_slots=N_SLOTS, segment_len=SEGMENT_LEN,
            max_len=PROMPT_LEN + GEN_LONG + SEGMENT_LEN)
        workload = _workload(cfg.vocab_size)

        _run_policy(engine, workload, "continuous")     # compile
        best = {"static": None, "continuous": None}
        for _ in range(REPEATS):
            for policy in ("static", "continuous"):
                r = _run_policy(engine, workload, policy)
                if best[policy] is None or r[0] < best[policy][0]:
                    best[policy] = r
        (t_s, tok_s, util_s, seg_s, comps_s) = best["static"]
        (t_c, tok_c, util_c, seg_c, comps_c) = best["continuous"]
        # the engine's bit-identity contract, enforced in the exact
        # binary CI runs: scheduling must not change a single token
        for a, b in zip(comps_s, comps_c):
            assert a.uid == b.uid and np.array_equal(a.tokens, b.tokens), \
                f"policies diverged on request {a.uid}"
        rows.append({
            "backend": backend,
            "n_slots": N_SLOTS, "segment_len": SEGMENT_LEN,
            "n_requests": N_REQUESTS, "total_tokens": tok_c,
            "static_tokens_per_s": tok_s / t_s,
            "continuous_tokens_per_s": tok_c / t_c,
            "static_slot_utilization": util_s,
            "continuous_slot_utilization": util_c,
            "static_segments": seg_s,
            "continuous_segments": seg_c,
            "continuous_speedup": t_s / t_c,
        })
    return rows


def main() -> List[str]:
    rows = run()
    out = ["continuous_batching,backend,static_tok_s,continuous_tok_s,"
           "static_util,continuous_util,speedup"]
    for r in rows:
        out.append(
            f"continuous_batching,{r['backend']},"
            f"{r['static_tokens_per_s']:.0f},"
            f"{r['continuous_tokens_per_s']:.0f},"
            f"{r['static_slot_utilization']:.2f},"
            f"{r['continuous_slot_utilization']:.2f},"
            f"{r['continuous_speedup']:.2f}")
    lin = next(r for r in rows if r["backend"] == "linear")
    claims = {
        # the acceptance bar: refilling freed slots beats batch-sync by
        # ≥1.5× aggregate tokens/s on the skewed mix
        "continuous_1p5x_over_static": lin["continuous_speedup"] >= 1.5,
        # deterministic form of the same claim for CI gating: segment
        # count is pure scheduling (device cost per segment is equal
        # across policies), so the ratio cannot flake under host load
        "continuous_1p5x_fewer_segments":
            lin["static_segments"] >= 1.5 * lin["continuous_segments"],
        "utilization_improves": all(
            r["continuous_slot_utilization"]
            > r["static_slot_utilization"] for r in rows),
    }
    for name, ok in claims.items():
        out.append(f"continuous_batching_claim,{name},"
                   f"{'PASS' if ok else 'FAIL'}")
    with open(BENCH_PATH, "w") as f:
        json.dump({"n_slots": N_SLOTS, "segment_len": SEGMENT_LEN,
                   "workload": {"n_requests": N_REQUESTS,
                                "prompt_len": PROMPT_LEN,
                                "gen_long": GEN_LONG,
                                "gen_short": GEN_SHORT},
                   "rows": rows, "claims": claims}, f, indent=2)
    return out


if __name__ == "__main__":
    print("\n".join(main()))
