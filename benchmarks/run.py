"""Benchmark harness — one module per paper table/figure.

  table1          paper Table 1: lookup time / memory / encode overhead
  figure1         paper Figure 1: accuracy of the four attention variants
  decode_scaling  Table-1 inside a full transformer (O(1) vs O(n) decode)
  mass_serving    the §2.2 retrieval scenario: encode once, query many
  roofline        §Roofline summary from the dry-run artifacts

``python -m benchmarks.run [--fast] [--only NAME]`` prints CSV lines.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced figure-1 steps")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import decode_scaling, figure1, mass_serving, \
        roofline, table1

    benches = {
        "table1": table1.main,
        "decode_scaling": decode_scaling.main,
        "mass_serving": mass_serving.main,
        "roofline": roofline.main,
        "figure1": (lambda: figure1.main(steps=240)) if args.fast
        else figure1.main,
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    failed = []
    for name, fn in benches.items():
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:  # report and continue
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
