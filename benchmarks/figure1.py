"""Paper Figure 1: validation accuracy of the four attention variants.

Claims validated (paper §5 / Figure 1):
  a) softmax attention reaches the best accuracy,
  b) the linear mechanisms are significantly better than no attention,
  c) gated linear ≥ basic linear,
  d) attention models converge faster than no-attention.

The CNN corpus cannot ship in this container; the synthetic cloze task
(repro/data/cloze.py) preserves its structure — entity-anonymised facts,
queries answerable only by reading the document.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs.paper_qa import QAConfig
from repro.data.cloze import ClozeTask
from repro.qa.train import TrainResult, train_qa


def run(steps: int = 600, seed: int = 0) -> Dict[str, TrainResult]:
    task = ClozeTask(n_entities=20, n_relations=20, n_facts=10,
                     seed=seed + 7)
    cfg = QAConfig(vocab_size=task.vocab_size, n_entities=20, lr=2e-3)
    out = {}
    for att in ("none", "linear", "gated_linear", "softmax",
                "second_order"):
        out[att] = train_qa(att, steps=steps, eval_every=steps // 6,
                            seed=seed, cfg=cfg, task=task)
    return out


def check_claims(results: Dict[str, TrainResult]) -> Dict[str, bool]:
    best = {k: r.best_acc for k, r in results.items()}
    t50 = {k: r.steps_to_acc(0.5) for k, r in results.items()}

    def reached(k):
        return t50[k] if t50[k] > 0 else 10**9

    return {
        "softmax_best": best["softmax"] >= max(
            best["linear"], best["gated_linear"]) - 0.02,
        "linear_beats_none": best["linear"] > best["none"] + 0.1,
        "gated_geq_linear": best["gated_linear"] >= best["linear"] - 0.02,
        "attention_converges_faster": min(
            reached("linear"), reached("gated_linear"),
            reached("softmax")) < reached("none"),
        # the paper's §6 proposal (our implementation, beyond-paper):
        # second-order recurrence must also clearly beat no-attention
        "second_order_beats_none":
            best["second_order"] > best["none"] + 0.1,
    }


def main(steps: int = 600) -> List[str]:
    results = run(steps=steps)
    claims = check_claims(results)
    out = ["figure1,variant,best_acc,final_acc,steps_to_50pct"]
    for k, r in results.items():
        out.append(f"figure1,{k},{r.best_acc:.3f},{r.final_acc:.3f},"
                   f"{r.steps_to_acc(0.5)}")
    for c, ok in claims.items():
        out.append(f"figure1_claim,{c},{'PASS' if ok else 'FAIL'}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
