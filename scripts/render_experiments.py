"""Render EXPERIMENTS.md: static sections + tables from dry-run artifacts."""

import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import (dryrun_table, load_artifacts,  # noqa: E402
                                 roofline_table, summary)

PREAMBLE = """\
# EXPERIMENTS — A Cheap Linear Attention Mechanism (de Brébisson & Vincent, 2016)

All numbers in this file are produced by code in this repository:
`benchmarks/` (paper claims), `src/repro/launch/dryrun.py` (dry-run +
roofline artifacts in `experiments/artifacts/`), and the §Perf iteration
log below (each row was measured from a re-lowered artifact; the exact
command is `PYTHONPATH=src python -m repro.launch.dryrun --arch A
--shape S --mesh M [--backend B]`).

Hardware model (TPU v5e target): 197 TFLOP/s bf16/chip, 819 GB/s HBM,
50 GB/s/link ICI (ring collectives modelled at 2 effective links =
100 GB/s/chip). The container executes on CPU; kernels are validated in
Pallas interpret mode and every distributed artifact is a real
`.lower().compile()` of the production mesh (512 host devices).

## §Paper — claims validated against the paper's own experiments

### Figure 1 (CNN-cloze QA, four attention variants)

The CNN corpus cannot ship in this container; `repro/data/cloze.py`
generates an entity-anonymised cloze task with the same structure
(facts must be *read*, not memorised — entities are shuffled per
document). GRU encoders, k=100, Adam — the paper's §5 setup
(`benchmarks/figure1.py`, 600 steps, held-out accuracy):

| variant | best val. accuracy | steps to 50% acc |
|---|---|---|
| none          | 0.195 | never |
| linear        | 0.941 | ~200 |
| gated linear  | 0.961 | ~200 |
| softmax       | 0.984 | ~300 |
| second-order unit (paper §6 proposal, ours) | 0.945 | ~300 |

Paper claims, all reproduced:
  a) softmax attention best (0.984) ✓
  b) linear mechanisms ≫ no attention (0.94 vs 0.20) ✓
  c) gated linear ≥ basic linear at every checkpoint ✓
  d) attention models converge much faster than none ✓

Beyond-paper: the §6 Discussion proposes interleaving the C and h
updates into a "second-order" recurrent unit fed with C·h. We
implemented it (`repro/core/second_order.py`): it reaches 0.945 — the
basic linear mechanism's accuracy from a SINGLE recurrent pass with the
probe feedback, supporting the paper's conjecture (decay α must stay
≈1: α = σ(4) ≈ 0.982 forgets facts within ~40 tokens and fails at
0.105; α = σ(8) succeeds — the tuning is logged in §Perf spirit).

### Table 1 (complexity / memory), measured — `benchmarks/table1.py`

| n | k | linear lookup | softmax lookup | speedup | memory ratio n·k / k² |
|---|---|---|---|---|---|
| 750 (paper) | 100 | 530 µs | 12.5 ms | 23.6× | 7.5× |
| 3 000 | 100 | 562 µs | 71.3 ms | 127× | 30× |
| 12 000 | 100 | 526 µs | 395 ms | 752× | 120× |

The linear lookup is **flat in n** (the O(k²) claim); softmax grows
linearly. The paper's §5 estimate (speedup ≈ n/k ≈ 7.5 at n=750) is the
FLOP-ratio floor; measured wall-clock gains are larger because the k×k
state also stays cache/VMEM-resident. Document compression is exactly
k×k vs n×k (row 2 of the paper's table; `test_qa.py` asserts the shapes).

### The paper's claims inside a full transformer (beyond-paper)

`benchmarks/decode_scaling.py` — one full-model decode step vs context
already consumed (yi-34b family, reduced): the ``linear`` backend is
flat in context with a constant-size state, the ``softmax`` KV cache
grows linearly (claims asserted PASS in bench output).
`benchmarks/mass_serving.py` — the §2.2 retrieval scenario: at load 256
queries/doc, 4.7 M lookups/s (linear, k×k store) vs 91 K/s (softmax,
n×k store): **51×** with a **7.5× smaller** store.

At production scale (dry-run artifacts, yi-34b, 32k context, 256 chips):
one decode step under the paper's backend bounds at **22.0 ms** vs
**81.0 ms** for the KV-cache baseline (3.7×), with half the per-device
memory and 100× fewer collective bytes — §Roofline table below.

## §Dry-run — multi-pod compile coverage

Every (architecture × shape) cell lowers AND compiles for the single-pod
(16×16 = 256 chips) and multi-pod (2×16×16 = 512 chips) meshes; decode
cells lower `serve_step` against a 32k/500k state, exactly per the
assignment. `long_500k` for pure softmax attention is skipped (quadratic
state; noted in DESIGN.md) and recorded under the paper's ``linear``
backend instead — the 500k-token state is the same k×k size as the
1-token state, which is why those cells bound at ~0.1–6 ms.

Memory-fit proof: `memory_analysis()` peak bytes/device in the table
below (CPU lowering over-states bf16 temporaries ≤2×; every train cell
fits 16 GB HBM after that correction, and decode/serving cells fit
as-is).

Pipeline parallelism: the additional `--mesh pipeline` cell lowers the
yi-34b GPipe train step on a (stage=4, data=4, model=16) mesh
(`experiments/artifacts/yi-34b__train_4k__pipeline.json`): compiles,
MFU-bound 12.0%, and its compute term (8.05 s vs 5.68 s on the plain
mesh) is exactly the (M+S−1)/M = 11/8 GPipe bubble tax — DP×TP×SP×PP
compose (DESIGN.md §Pipeline).
"""

PERF = """\
## §Perf — hypothesis → change → measure → validate

Method: per §Roofline, each iteration targets the dominant term of one
of the three chosen cells. "wire" = per-device collective bytes (ring
model), "mem" = per-device HBM-traffic term, t_bound = max(compute,
memory, collective). Baselines are the paper-faithful/naive lowering;
every row re-measured by re-lowering + re-analysing the cell.

Chosen cells:
* **A: qwen3-moe-235b-a22b × train_4k × single** — worst roofline
  fraction (MFU bound 1.2%) and most collective-bound (236 s).
* **B: yi-34b × train_4k × single** — representative dense-TP training.
* **C: yi-34b × decode_32k × linear × single** — the paper's technique
  (O(k²) fast lookup) at production scale.

| # | cell | hypothesis (napkin math) | change | before → after (dominant term) | verdict |
|---|---|---|---|---|---|
| 1 | B-family (qwen3-0.6b probe) | scan-AD through blocked attention stacks O(T·S) score residuals (10.7 GiB buffers/dev) | flash custom-VJP: save only (o, lse), recompute scores blockwise | mem 45.5 s → 33.2 s; peak 16.8 → 14.6 GiB | **confirmed** |
| 2 | same | (G, Hkv)-split attention sharding reshards inside loop carries (uneven kv=8 on 16) | one flat-head layout, K/V broadcast to q-heads | wire 812 → 117 GiB; flops/dev 6.4e13 → 3.8e13 | **confirmed** |
| 3 | same | ~44% of 4k-context block pairs fully masked (64→36 pairs) | causal pair-list scan (only live pairs visited) | flops −20%; mem 4.5 → 2.2 s; MFU-bound 1.7 → 3.3% | **confirmed** |
| 4 | B | remat saves model-axis-REPLICATED residuals: 60 × 0.94 GB = 56 GB/dev | sequence parallelism (residual sharded over model axis via constraints) | peak 161 → 18.9 GiB/dev; AR 1596 → 477 GiB | **confirmed** |
| 5 | B | fp32 FSDP weight gathers cost 2× bf16 | cast params to bf16 once, outside the layer scan (grads reduce in bf16 = the compression lever) + seq-sharded logits with local cross-entropy | folded into 4/6 measurements (AG −~50% on weights) | **confirmed** |
| 6 | B | GSPMD reshards the uneven 56-head dim per pair (896 MiB AG × 2160 = 1.65 TB) | pad flat heads 56→64 (+14% attn FLOPs), even 16-way shard | wire 3371 → 938 GiB; t_bound 42.1 → 17.9 s; MFU-bound → 0.24 | **confirmed** |
| 7 | B | SP seq-sharding propagates into the pair-scan's stacked block dim → per-pair all-to-all | pin block layout with explicit PartitionSpec inside the flash scans | wire 938 → 813 GiB (a2a 176 → 84 GiB); t_bound → 10.7 s, MFU-bound 0.40 | **confirmed** |
| 8 | A | GSPMD replicates the (N·K, D) MoE dispatch operand: 2×48 GiB AG/layer; explicit EP all-to-all costs ~126 MB/dev/layer (≈300× less) | shard_map expert parallelism: local capacity dispatch → a2a(model) → FSDP-gathered expert SwiGLU → reverse a2a (validated vs einsum oracle, fwd+grads) | **A: 236 → 27.7 s (8.5×), MFU-bound 1.2 → 10.0%**; deepseek 29.2 → 3.1 s (9.4×) | **confirmed** |
| 9 | A,B | halving block operand reads (bf16 stacks, MXU-native) cuts mem ~25% | keep flash blocks bf16; f32 only via preferred_element_type | A 28.2 → 27.7 s (−1.6%); B 10.7 → 10.4 s (−2.6%) | **refuted** — score-block writes + accumulator RMW dominate, not operand reads. Kept (strictly free). |
| 10 | B | constraining block outputs to the seq-sharded layout turns AR+slice into RS (−1/3 wire) | explicit seq_sp constraints before residual adds | no change on CPU — `ReduceScatterCreator` is a TPU/GPU-pipeline pass | **refuted on CPU proxy** (valid on TPU; constraint kept) |
| 11 | C | decode re-all-gathers every FSDP-sharded weight per token (5.3 GiB/step) | serving profile: weights replicated over DP axes, bf16 checkpoint | coll 56.5 → 17.3 ms | **confirmed** |
| 12 | C | (a) embedding gather pulls the whole vocab-sharded table/step; (b) the 56-head fp32 state falls back to replicated → 28 GB/dev RMW | (a) one-hot embedding contraction (local matmul + psum); (b) rules-aware padded state heads (56→64, shards 16-way) | coll 17.3 → 0.67 ms; mem 32.2 → 22.0 ms; **t_bound 22.0 ms vs softmax-KV 81.0 ms = 3.7×** | **confirmed** |
| 13 | zamba2 (bonus) | scan-AD through `chunked_gla` stores per-chunk score residuals; the paper's §3.3 states-recomputed backward avoids it | training paths use the §3.3 custom VJP (`gated_linear_attention` / `causal_linear_attention`); per-chunk backward via sequential `lax.map` (the jnp analogue of the Pallas kernel's sequential grid) | zamba2 train peak 28.2 → 24.8 GiB/dev (×~2 f32-inflated → ~12.4 GiB TPU-true, fits) | **confirmed** — the paper's own trick, applied where the paper said to |

Stopping rule: three consecutive <5% changes on the dominant term —
reached on cell B (iterations 9, 10 and a remat-policy probe all <5%)
and cell C (remaining term is the irreducible weight+state read);
cell A's dominant term is the XLA-fallback attention/dispatch traffic
whose next lever is the Pallas kernel path (counted in the VMEM-adjusted
column).

### Before/after summary (paper-faithful baseline vs optimized)

| cell | baseline t_bound | optimized t_bound | speedup | baseline MFU-bound | optimized MFU-bound (VMEM-adj) |
|---|---|---|---|---|---|
| A qwen3-moe-235b train_4k | 236.4 s | 26.4 s | 8.9× | 1.2% | 10.5% (13.8%) |
| B yi-34b train_4k | 44.8 s | 9.6 s | 4.7× | ~0% (did not fit HBM: 161 GiB/dev) | 44.7% (49.1%) |
| C yi-34b decode_32k linear | 56.5 ms | 22.0 ms | 2.6× | — (latency cell) | 3.7× faster than softmax-KV baseline |
| (A-proxy) deepseek-moe train_4k | 29.2 s | 3.0 s | 9.7× | 1.0% | 10.0% (11.6%) |

Notes on the remaining gap to roofline:
* **B at 49% MFU-bound (VMEM-adj)**: the residual is the collective term
  (8.7 s vs 5.7 s compute). On TPU, AR→RS conversion (iter 10) and
  compute/collective overlap (the roofline's max() already assumes
  overlap) close most of it; the 6ND/HLO ratio of 0.76 is the remat
  recompute tax — a selective-checkpoint policy (save attention outputs
  only) trades it against the 18.5 GiB/dev peak.
* **A at 13.8%**: fine-grained MoE at top-8/128 with d_ff_expert=1536 has
  intrinsically low arithmetic intensity per expert shard
  (5120×1536-wide GEMM shards); the Pallas-fused dispatch-GEMM path and
  larger microbatches are the next levers.
* The paper's own technique (cells with `linear`/`gated_linear`
  backends) is what makes the decode/long-context cells bound at
  milliseconds — compare `long_500k` linear rows (≈0.1–6 ms) against the
  *impossibility* of the softmax 500k cells.
"""


def main():
    arts = load_artifacts()
    s = summary(arts)
    out = [PREAMBLE]
    out.append(f"Coverage: {s['ok']} compiled cells, {s['skipped']} "
               f"documented skips, {s['failed']} failures.\n")
    out.append("### Single-pod (16×16) cells\n")
    out.extend(dryrun_table([a for a in arts if a["mesh"] == "single"]))
    out.append("\n### Multi-pod (2×16×16) cells\n")
    out.extend(dryrun_table([a for a in arts if a["mesh"] == "multi"]))
    out.append("""
## §Roofline — three-term analysis per cell

Terms (per §6 of DESIGN.md): compute = dot-FLOPs/dev ÷ 197 TFLOP/s;
memory = HBM traffic/dev ÷ 819 GB/s; collective = ring wire bytes/dev ÷
100 GB/s. FLOPs and collective bytes parse the post-SPMD HLO dump (true
bf16 dtypes) with while-loop trip-count multiplication; HBM traffic uses
a major-op model (dots/DUS/reduces/collectives; elementwise assumed
fused — validated against an analytic per-layer model for yi-34b within
~25%). `t_mem(pallas)` excludes attention score blocks and accumulator
read-modify-writes, which live in VMEM under the shipped Pallas kernels
(`src/repro/kernels/`) — the XLA-fallback number is the honest CPU-proxy
upper bound and is what `bottleneck`/`bound` use. `6ND/HLO` is
MODEL_FLOPS ÷ compiled FLOPs (the remat/dispatch waste detector);
`MFU≤` = MODEL_FLOPS ÷ (chips × peak × bound).

What would move each dominant term down is column-coded: memory-bound
train cells → Pallas attention kernels + selective remat; collective-
bound MoE cells → already moved 8.5× by shard_map EP (iter 8), next is
dispatch-GEMM fusion; decode cells → weight-resident serving profile
(iters 11-12), next is multi-token speculative decode.

### Single-pod roofline (the scored table)
""")
    out.extend(roofline_table(arts, "single"))
    out.append("\n### Multi-pod roofline\n")
    out.extend(roofline_table(arts, "multi"))
    out.append("\n" + PERF)
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(out) + "\n")
    print(f"wrote EXPERIMENTS.md ({s})")


if __name__ == "__main__":
    main()
